//! Per-kernel GEMM cost model, one entry per bit-width paradigm.
//!
//! time = max(compute, memory) + inner_loop_overhead + epilogue + launch
//!
//! The distinguishing term is `inner_loop_overhead`: work that the
//! paradigm forces onto the CUDA-core ALUs *inside* the K loop, where it
//! cannot hide behind Tensor Core math:
//!
//! * fine-grained W4A8 (Eq. 5): one Integer2Float + FMA per output element
//!   per K-group — `M*N*(K/G) * 2` ALU ops.
//! * asymmetric W4A8: s8 subtraction is unsupported (PTX has no sub.s8);
//!   operands widen to s32 — modeled as `M*N*K / 4` extra ALU ops (one
//!   widened op per 4-element packed word) plus the zero-point correction.
//! * unfused conversion (Fig. 4(b)): a separate kernel materializes the
//!   s8 weights — an extra HBM write+read of K*N bytes and a second launch.
//! * FastGEMM: conversion folds into the shared-memory load (free behind
//!   the MXU/TC pipeline); only the ÷16-adjusted per-channel epilogue
//!   remains: `M*N` FMAs AFTER the GEMM.
//! * QUIK W4A4+outliers: three separate kernels (int4 GEMM on the dense
//!   part, fp16 GEMM on outlier columns, gather/add) with their own
//!   launches and aggregated I/O — the paper's A.2 analysis.

use super::GpuSpec;

/// GEMM paradigms (mirror the kernel/artifact variant names).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GemmKind {
    Fp16,
    W8A8,
    W4A8Fast,
    W4A8Group,
    W4A8Asym,
    W4A8Unfused,
    W4A16,
    /// QUIK-style W4A4 with an fp16 outlier fallback
    QuikW4A4 { outlier_frac_x1000: u32 },
    /// bitsandbytes NF4: codebook-dequantize the full weight matrix to a
    /// materialized fp16 copy, then run a plain fp16 GEMM (appendix A.3)
    Nf4 { group: u32 },
}

impl GemmKind {
    pub fn from_variant(v: &str) -> Option<Self> {
        Some(match v {
            "fp" => GemmKind::Fp16,
            "w8a8" => GemmKind::W8A8,
            "w4a8_fast" => GemmKind::W4A8Fast,
            "w4a8_group" => GemmKind::W4A8Group,
            "w4a8_asym" => GemmKind::W4A8Asym,
            "w4a8_unfused" => GemmKind::W4A8Unfused,
            "w4a16" => GemmKind::W4A16,
            _ => return None,
        })
    }

    /// weight bytes per element
    pub fn w_bytes(&self) -> f64 {
        match self {
            GemmKind::Fp16 => 2.0,
            GemmKind::W8A8 => 1.0,
            GemmKind::W4A8Fast
            | GemmKind::W4A8Group
            | GemmKind::W4A8Asym
            | GemmKind::W4A8Unfused
            | GemmKind::W4A16 => 0.5,
            GemmKind::QuikW4A4 { .. } => 0.5,
            GemmKind::Nf4 { .. } => 0.5,
        }
    }

    /// activation bytes per element
    pub fn a_bytes(&self) -> f64 {
        match self {
            GemmKind::Fp16 | GemmKind::W4A16 | GemmKind::Nf4 { .. } => 2.0,
            GemmKind::QuikW4A4 { .. } => 0.5,
            _ => 1.0,
        }
    }

    /// math throughput (ops/s) on the spec
    fn mac_rate(&self, g: &GpuSpec) -> f64 {
        match self {
            GemmKind::Fp16 | GemmKind::W4A16 | GemmKind::Nf4 { .. } => {
                g.fp16_tc
            }
            GemmKind::QuikW4A4 { .. } => g.int4_tc,
            _ => g.int8_tc,
        }
    }
}

/// Cost breakdown for one GEMM call (seconds).
#[derive(Clone, Debug, Default)]
pub struct GemmCost {
    pub compute_s: f64,
    pub memory_s: f64,
    pub overhead_s: f64,
    pub launch_s: f64,
}

impl GemmCost {
    pub fn total(&self) -> f64 {
        self.compute_s.max(self.memory_s) + self.overhead_s + self.launch_s
    }
}

/// Model one `[M,K] x [K,N]` GEMM under `kind`.
pub fn gemm_cost(
    g: &GpuSpec,
    kind: GemmKind,
    m: usize,
    n: usize,
    k: usize,
    group: usize,
) -> GemmCost {
    let (mf, nf, kf) = (m as f64, n as f64, k as f64);
    let macs = 2.0 * mf * nf * kf;
    let alu = g.alu_fp32 * g.eff_compute;
    let bw = g.hbm_bw * g.eff_mem;

    // ---- base streams: weights + activations + f16 output
    let mut bytes = kf * nf * kind.w_bytes() + mf * kf * kind.a_bytes()
        + mf * nf * 2.0;
    // ---- per-channel / per-group scale streams
    let groups = if group > 0 { (k / group) as f64 } else { 1.0 };
    bytes += nf * groups * 2.0;

    let mut compute = macs / (kind.mac_rate(g) * g.eff_compute);
    let mut overhead = 0.0;
    let mut launch = g.kernel_launch;

    match kind {
        GemmKind::Fp16 => {}
        GemmKind::W8A8 => {
            // per-channel dequant epilogue: one FMA per output element
            overhead += mf * nf / alu;
        }
        GemmKind::W4A8Fast => {
            // fused conversion hides behind TC math; epilogue identical
            // to W8A8 (the /16 folds into the scale)
            overhead += mf * nf / alu;
        }
        GemmKind::W4A8Group => {
            // per-group I2F + FMA inside the K loop: 2 ALU ops per
            // output element per group (Eq. 5's Dq)
            overhead += mf * nf * groups * 2.0 / alu;
        }
        GemmKind::W4A8Asym => {
            // widened s32 zero-point handling: ~one extra ALU op per MAC/4
            // (per packed word) + correction term
            overhead += (mf * nf * kf / 4.0) / alu;
            overhead += mf * nf / alu;
        }
        GemmKind::W4A8Unfused => {
            // separate conversion kernel (Fig. 4(b)): write + read the
            // materialized s8 weights, and a second launch
            bytes += 2.0 * kf * nf;
            launch += g.kernel_launch;
            overhead += mf * nf / alu;
        }
        GemmKind::W4A16 => {
            // dequant to fp16 BEFORE the GEMM: I2F+FMA per weight element
            // on CUDA cores (cannot ride the TC pipeline)
            overhead += kf * nf * 2.0 / alu;
        }
        GemmKind::Nf4 { group } => {
            // separate dequant kernel: read packed int4 + absmax scales,
            // codebook-lookup per element (~8 lookup-bound ALU ops), and
            // WRITE + re-READ the fp16 weight copy before the GEMM
            bytes += 2.0 * 2.0 * kf * nf; // fp16 materialization round-trip
            bytes += kf * nf / group as f64 * 2.0; // absmax blocks
            overhead += kf * nf * 8.0 / (alu * 0.5);
            launch += g.kernel_launch; // the dequant kernel
        }
        GemmKind::QuikW4A4 { outlier_frac_x1000 } => {
            let of = outlier_frac_x1000 as f64 / 1000.0;
            // dense int4 part.  The outlier split prevents full-tile
            // occupancy, so QUIK's W4A4 CUTLASS kernels land at roughly
            // INT8-level effective throughput (the paper's A.2: 'ideally
            // pure W4A4 would be 2x faster ... the benefit vanishes').
            compute = macs * (1.0 - of) / (g.int8_tc * g.eff_compute);
            // skinny fp16 outlier GEMM
            let t_out = macs * of / (g.fp16_tc * g.eff_compute * 0.5);
            overhead += t_out;
            // QUIK runs ~6 separate kernels per linear: act-quant,
            // int4 GEMM, outlier gather, outlier fp16 GEMM, dequant, add
            // — each with its own launch + tail (A.2 'aggregated I/O
            // overhead on various kernels')
            launch += 5.0 * g.kernel_launch;
            // aggregated I/O: act-quant pass (read+write M*K), outlier
            // activations in fp16, and an s32->f16 output round-trip
            bytes += 2.0 * mf * kf + mf * kf * of * 2.0
                + mf * nf * (4.0 + 2.0);
            overhead += mf * nf / alu;
        }
    }

    GemmCost {
        compute_s: compute,
        memory_s: bytes / bw,
        overhead_s: overhead,
        launch_s: launch,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g() -> GpuSpec {
        GpuSpec::a100_80g()
    }

    #[test]
    fn decode_is_memory_bound() {
        // M=1 self-decode: memory dominates compute for every paradigm
        let c = gemm_cost(&g(), GemmKind::W4A8Fast, 1, 4096, 4096, 0);
        assert!(c.memory_s > c.compute_s);
    }

    #[test]
    fn context_is_compute_bound() {
        let c = gemm_cost(&g(), GemmKind::Fp16, 1024, 4096, 4096, 0);
        assert!(c.compute_s > c.memory_s);
    }

    #[test]
    fn fastgemm_beats_group_and_asym() {
        // Fig. 7's ordering at a context shape
        let f = gemm_cost(&g(), GemmKind::W4A8Fast, 1024, 4096, 4096, 0)
            .total();
        let gr = gemm_cost(&g(), GemmKind::W4A8Group, 1024, 4096, 4096, 128)
            .total();
        let a = gemm_cost(&g(), GemmKind::W4A8Asym, 1024, 4096, 4096, 0)
            .total();
        assert!(f < gr, "fast {f} vs group {gr}");
        assert!(f < a, "fast {f} vs asym {a}");
    }

    #[test]
    fn w4_halves_decode_traffic_vs_w8() {
        let w4 = gemm_cost(&g(), GemmKind::W4A8Fast, 1, 8192, 8192, 0);
        let w8 = gemm_cost(&g(), GemmKind::W8A8, 1, 8192, 8192, 0);
        let ratio = w8.memory_s / w4.memory_s;
        assert!(
            ratio > 1.7 && ratio < 2.2,
            "weight-dominated traffic should nearly halve: {ratio}"
        );
    }

    #[test]
    fn quik_loses_self_decode_wins_nothing_at_m1() {
        // the paper's Table 5: at M=1 QUIK's multi-kernel overhead swamps
        // the int4 math advantage
        let quik = gemm_cost(
            &g(),
            GemmKind::QuikW4A4 { outlier_frac_x1000: 50 },
            1,
            4096,
            4096,
            0,
        )
        .total();
        let fast =
            gemm_cost(&g(), GemmKind::W4A8Fast, 1, 4096, 4096, 0).total();
        assert!(
            quik / fast > 2.0 && quik / fast < 6.0,
            "QUIK should be ~3-4x slower at M=1 (paper: 4.33x): {}",
            quik / fast
        );
    }

    #[test]
    fn w4a16_slow_in_context_fast_in_decode() {
        // Sec 4.1: W4A16 wins self-decode (bytes) but loses pre-fill
        // (dequant overhead + fp16 math)
        let ctx16 =
            gemm_cost(&g(), GemmKind::W4A16, 1024, 4096, 4096, 128).total();
        let ctx8 =
            gemm_cost(&g(), GemmKind::W8A8, 1024, 4096, 4096, 0).total();
        assert!(ctx16 > ctx8);
        let dec16 =
            gemm_cost(&g(), GemmKind::W4A16, 1, 4096, 4096, 128).total();
        let dec_fp =
            gemm_cost(&g(), GemmKind::Fp16, 1, 4096, 4096, 0).total();
        assert!(dec16 < dec_fp);
    }
}
