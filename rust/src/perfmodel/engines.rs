//! Engine comparator profiles: ours, TensorRT-LLM, HuggingFace eager,
//! HuggingFace + bitsandbytes NF4.
//!
//! Profiles differ only in *structural* properties, not fitted constants:
//! kernel fusion quality (per-layer fixed overhead), host-sync cost per
//! decode step, and — for NF4 — the normal-float dequantization that runs
//! a lookup + rescale on CUDA cores for every weight element before an
//! fp16 GEMM (bitsandbytes' documented design).

use super::gemm::{gemm_cost, GemmKind};
use super::llm::{e2e_latency, EngineOverhead, LlmShape, PhaseLatency};
use super::GpuSpec;

/// The engines compared in Tables 4 and 7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// our CUTLASS-style engine (the paper's "Ours")
    Ours,
    /// TensorRT-LLM: equally fused, marginally better scheduling
    TrtLlm,
    /// HuggingFace transformers eager: per-op kernels, python host loop
    HfEager,
    /// HuggingFace + bitsandbytes NF4 4-bit
    HfNf4,
}

impl EngineKind {
    pub fn overhead(&self) -> EngineOverhead {
        match self {
            // tight kernel fusion: tiny per-layer cost, fast sampler
            EngineKind::Ours => EngineOverhead {
                per_layer_s: 1.0e-6,
                per_step_s: 30e-6,
                gemm_scale: 1.0,
            },
            // TRT-LLM's scheduler is a bit tighter than ours per step,
            // kernels comparable (paper Table 4 shows ours ~5% slower
            // at FP16)
            EngineKind::TrtLlm => EngineOverhead {
                per_layer_s: 0.8e-6,
                per_step_s: 25e-6,
                gemm_scale: 0.97,
            },
            // eager mode: every op its own kernel + python dispatch
            // (~10 extra launches/layer) and a slow host sampling loop
            EngineKind::HfEager => EngineOverhead {
                per_layer_s: 45e-6,
                per_step_s: 2.0e-3,
                gemm_scale: 1.25,
            },
            // NF4 inherits eager overheads; GEMM cost handled separately
            EngineKind::HfNf4 => EngineOverhead {
                per_layer_s: 45e-6,
                per_step_s: 2.0e-3,
                gemm_scale: 1.0,
            },
        }
    }

    /// Engine-specific end-to-end latency.
    pub fn latency(
        &self,
        g: &GpuSpec,
        shape: &LlmShape,
        kind: GemmKind,
        batch: usize,
        in_tokens: usize,
        out_tokens: usize,
        group: usize,
    ) -> PhaseLatency {
        match self {
            EngineKind::HfNf4 => {
                // bitsandbytes NF4 GEMMs + eager-mode dispatch overheads
                let oh = self.overhead();
                e2e_latency(
                    g,
                    shape,
                    GemmKind::Nf4 { group: 64 },
                    &oh,
                    batch,
                    in_tokens,
                    out_tokens,
                    0,
                )
            }
            _ => e2e_latency(
                g,
                shape,
                kind,
                &self.overhead(),
                batch,
                in_tokens,
                out_tokens,
                group,
            ),
        }
    }
}

/// QUIK per-kernel comparator (paper Table 5): our FastGEMM vs QUIK's
/// multi-kernel W4A4-with-outliers at a given (M, N, K).
pub fn quik_vs_fastgemm(
    g: &GpuSpec,
    m: usize,
    n: usize,
    k: usize,
) -> (f64, f64) {
    let quik = gemm_cost(
        g,
        GemmKind::QuikW4A4 { outlier_frac_x1000: 50 },
        m,
        n,
        k,
        0,
    )
    .total();
    let fast = gemm_cost(g, GemmKind::W4A8Fast, m, n, k, 0).total();
    (quik, fast)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g() -> GpuSpec {
        GpuSpec::a100_80g()
    }

    #[test]
    fn trt_fp16_close_to_ours_fp16() {
        let s = LlmShape::llama2_13b();
        let ours = EngineKind::Ours
            .latency(&g(), &s, GemmKind::Fp16, 1, 1024, 128, 0)
            .total();
        let trt = EngineKind::TrtLlm
            .latency(&g(), &s, GemmKind::Fp16, 1, 1024, 128, 0)
            .total();
        let ratio = ours / trt;
        // paper Table 4: ours within ~8% of TRT at FP16
        assert!(ratio > 1.0 && ratio < 1.15, "ratio {ratio}");
    }

    #[test]
    fn our_w4a8_beats_trt_fp16_by_about_2x() {
        let s = LlmShape::llama2_13b();
        let trt_fp16 = EngineKind::TrtLlm
            .latency(&g(), &s, GemmKind::Fp16, 1, 1024, 128, 0)
            .total();
        let ours_w4a8 = EngineKind::Ours
            .latency(&g(), &s, GemmKind::W4A8Fast, 1, 1024, 128, 0)
            .total();
        let boost = trt_fp16 / ours_w4a8;
        // paper: 2.23x for 13B — the model should land in the band
        assert!(boost > 1.6 && boost < 3.0, "boost {boost}");
    }

    #[test]
    fn hf_eager_much_slower() {
        let s = LlmShape::llama2_7b();
        let hf = EngineKind::HfEager
            .latency(&g(), &s, GemmKind::Fp16, 1, 1024, 128, 0)
            .total();
        let ours = EngineKind::Ours
            .latency(&g(), &s, GemmKind::W4A8Fast, 1, 1024, 128, 0)
            .total();
        let boost = hf / ours;
        // paper Table 7: 4.57x for 7B bs=1
        assert!(boost > 3.0 && boost < 7.0, "boost {boost}");
    }

    #[test]
    fn nf4_slower_than_hf_fp16() {
        // paper A.3: the HF 4-bit NF4 path is SLOWER than HF fp16
        let s = LlmShape::llama2_7b();
        let fp16 = EngineKind::HfEager
            .latency(&g(), &s, GemmKind::Fp16, 1, 1024, 128, 0)
            .total();
        let nf4 = EngineKind::HfNf4
            .latency(&g(), &s, GemmKind::Fp16, 1, 1024, 128, 64)
            .total();
        assert!(nf4 > fp16, "nf4 {nf4} vs fp16 {fp16}");
    }

    #[test]
    fn quik_table5_shape() {
        // context decode: roughly on par; self-decode: >=3x
        let (q_ctx, f_ctx) = quik_vs_fastgemm(&g(), 1024, 4096, 4096);
        let (q_dec, f_dec) = quik_vs_fastgemm(&g(), 1, 4096, 4096);
        assert!(q_ctx / f_ctx < 1.6, "context parity: {}", q_ctx / f_ctx);
        assert!(q_dec / f_dec > 2.5, "self-decode win: {}", q_dec / f_dec);
    }
}
