//! LLaMA-family shape catalog + end-to-end phase latency composition.
//!
//! An inference pass = context decoding (prefill, M = batch·seq) followed
//! by `out_tokens` self-decode steps (M = batch).  Each step runs the
//! seven per-layer GEMMs plus the LM head; attention math and KV-cache
//! traffic are modeled separately (they are bit-width independent except
//! through activation precision).

use super::gemm::{gemm_cost, GemmKind};
use super::GpuSpec;

/// Transformer shape (per tensor-parallel rank).
#[derive(Clone, Debug)]
pub struct LlmShape {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// KV projection width (GQA: < d_model)
    pub kv_dim: usize,
    pub tp: usize,
}

impl LlmShape {
    pub fn llama2_7b() -> Self {
        LlmShape {
            name: "LLaMA-2-7B",
            n_layers: 32,
            d_model: 4096,
            d_ff: 11008,
            vocab: 32000,
            kv_dim: 4096,
            tp: 1,
        }
    }

    pub fn llama2_13b() -> Self {
        LlmShape {
            name: "LLaMA-2-13B",
            n_layers: 40,
            d_model: 5120,
            d_ff: 13824,
            vocab: 32000,
            kv_dim: 5120,
            tp: 1,
        }
    }

    pub fn llama2_70b() -> Self {
        LlmShape {
            name: "LLaMA-2-70B",
            n_layers: 80,
            d_model: 8192,
            d_ff: 28672,
            vocab: 32000,
            kv_dim: 1024, // GQA: 8 kv heads * 128
            tp: 4,
        }
    }

    pub fn llama1_13b() -> Self {
        LlmShape {
            name: "LLaMA-13B",
            n_layers: 40,
            d_model: 5120,
            d_ff: 13824,
            vocab: 32000,
            kv_dim: 5120,
            tp: 1,
        }
    }

    /// The per-layer GEMMs as (N, K) with TP sharding applied.
    pub fn layer_gemms(&self) -> Vec<(usize, usize)> {
        let d = self.d_model;
        let ff = self.d_ff / self.tp;
        let kv = self.kv_dim / self.tp;
        let dh = d / self.tp;
        vec![
            (dh, d),  // wq
            (kv, d),  // wk
            (kv, d),  // wv
            (d, dh),  // wo
            (ff, d),  // gate
            (ff, d),  // up
            (d, ff),  // down
        ]
    }

    /// Total weight bytes per rank at `w_bytes` per element.
    pub fn weight_bytes(&self, w_bytes: f64) -> f64 {
        let per_layer: f64 = self
            .layer_gemms()
            .iter()
            .map(|&(n, k)| (n * k) as f64)
            .sum();
        (per_layer * self.n_layers as f64
            + (self.d_model * self.vocab) as f64 / self.tp as f64)
            * w_bytes
    }
}

/// Phase latencies in seconds.
#[derive(Clone, Debug, Default)]
pub struct PhaseLatency {
    pub context_s: f64,
    pub self_decode_s: f64,
}

impl PhaseLatency {
    pub fn total(&self) -> f64 {
        self.context_s + self.self_decode_s
    }
}

/// Per-step engine overhead beyond the GEMMs (kernel scheduling, layout,
/// sampling) — the knob that distinguishes engines (see `engines`).
#[derive(Clone, Debug)]
pub struct EngineOverhead {
    /// extra fixed time per layer per step (fusion quality)
    pub per_layer_s: f64,
    /// extra fixed time per decode step (host sync, sampling)
    pub per_step_s: f64,
    /// multiplier on every GEMM (kernel quality vs the tuned model)
    pub gemm_scale: f64,
}

impl Default for EngineOverhead {
    fn default() -> Self {
        EngineOverhead { per_layer_s: 1.0e-6, per_step_s: 30e-6, gemm_scale: 1.0 }
    }
}

/// Elementwise / auxiliary kernels per layer (norms x2, rope, residual
/// adds x2, SwiGLU, activation quant): ~6 extra kernel launches and ~12
/// read/write passes over the hidden state in fp16.  Bit-width
/// independent — this is what keeps real end-to-end boosts below the pure
/// GEMM ratio.
fn elementwise_layer_cost(g: &GpuSpec, m: usize, d_model: usize) -> f64 {
    let bytes = 12.0 * (m * d_model) as f64 * 2.0;
    bytes / (g.hbm_bw * g.eff_mem) + 6.0 * g.kernel_launch
}

/// Attention + KV traffic for one decode step (fp16 KV).
fn attention_decode_cost(
    g: &GpuSpec,
    shape: &LlmShape,
    batch: usize,
    past: usize,
) -> f64 {
    // per layer: read past KV (2 tensors) + dot products
    let kv_bytes = 2.0 * (past * shape.kv_dim / shape.tp) as f64 * 2.0
        * batch as f64;
    let macs = 2.0 * 2.0 * (past * shape.d_model / shape.tp) as f64
        * batch as f64;
    let mem = kv_bytes / (g.hbm_bw * g.eff_mem);
    let cmp = macs / (g.fp16_tc * g.eff_compute);
    mem.max(cmp) + g.kernel_launch
}

/// Attention cost for the context phase (S×S scores, fp16).
fn attention_context_cost(
    g: &GpuSpec,
    shape: &LlmShape,
    batch: usize,
    seq: usize,
) -> f64 {
    let macs = 2.0 * 2.0
        * (seq * seq * shape.d_model / shape.tp) as f64
        * batch as f64;
    macs / (g.fp16_tc * g.eff_compute) + g.kernel_launch
}

/// End-to-end latency for (kind, batch, in_tokens, out_tokens).
pub fn e2e_latency(
    g: &GpuSpec,
    shape: &LlmShape,
    kind: GemmKind,
    overhead: &EngineOverhead,
    batch: usize,
    in_tokens: usize,
    out_tokens: usize,
    group: usize,
) -> PhaseLatency {
    let l = shape.n_layers as f64;

    // ---- context phase
    let m_ctx = batch * in_tokens;
    let mut ctx = 0.0;
    for &(n, k) in &shape.layer_gemms() {
        ctx += gemm_cost(g, kind, m_ctx, n, k, group).total()
            * overhead.gemm_scale;
    }
    ctx += attention_context_cost(g, shape, batch, in_tokens);
    ctx += elementwise_layer_cost(g, m_ctx, shape.d_model);
    ctx += overhead.per_layer_s;
    ctx *= l;
    // LM head once (fp16)
    ctx += gemm_cost(
        g,
        GemmKind::Fp16,
        batch,
        shape.vocab / shape.tp,
        shape.d_model,
        0,
    )
    .total();
    ctx += overhead.per_step_s;

    // ---- self-decode phase
    let mut dec = 0.0;
    for step in 0..out_tokens {
        let past = in_tokens + step;
        let mut t = 0.0;
        for &(n, k) in &shape.layer_gemms() {
            t += gemm_cost(g, kind, batch, n, k, group).total()
                * overhead.gemm_scale;
        }
        t += attention_decode_cost(g, shape, batch, past);
        t += elementwise_layer_cost(g, batch, shape.d_model);
        t += overhead.per_layer_s;
        t *= l; // the sums above cover ONE layer
        dec += t;
        dec += gemm_cost(
            g,
            GemmKind::Fp16,
            batch,
            shape.vocab / shape.tp,
            shape.d_model,
            0,
        )
        .total();
        dec += overhead.per_step_s;
    }

    PhaseLatency { context_s: ctx, self_decode_s: dec }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g() -> GpuSpec {
        GpuSpec::a100_80g()
    }

    #[test]
    fn shapes_param_counts() {
        // sanity: 7B params within 15%
        let s = LlmShape::llama2_7b();
        let params = s.weight_bytes(1.0);
        assert!(
            (params - 6.7e9).abs() / 6.7e9 < 0.15,
            "7B params modeled as {params:.3e}"
        );
    }

    #[test]
    fn w4a8_beats_fp16_both_phases() {
        let s = LlmShape::llama2_13b();
        let oh = EngineOverhead::default();
        let f16 = e2e_latency(&g(), &s, GemmKind::Fp16, &oh, 1, 1024, 128, 0);
        let w48 =
            e2e_latency(&g(), &s, GemmKind::W4A8Fast, &oh, 1, 1024, 128, 0);
        assert!(w48.context_s < f16.context_s);
        assert!(w48.self_decode_s < f16.self_decode_s);
        let boost = f16.total() / w48.total();
        // paper Fig. 6: ~1.9-2.2x for 13B
        assert!(boost > 1.5 && boost < 3.5, "boost {boost}");
    }

    #[test]
    fn w4a16_wins_decode_loses_context_vs_w8a8() {
        let s = LlmShape::llama2_7b();
        let oh = EngineOverhead::default();
        let w8 = e2e_latency(&g(), &s, GemmKind::W8A8, &oh, 1, 1024, 128, 0);
        let w416 =
            e2e_latency(&g(), &s, GemmKind::W4A16, &oh, 1, 1024, 128, 128);
        assert!(w416.context_s > w8.context_s, "W4A16 slower prefill");
        assert!(w416.self_decode_s < w8.self_decode_s, "W4A16 faster decode");
    }

    #[test]
    fn decode_dominates_total() {
        // 128 output tokens at batch 1: self-decode >> context (Fig. 1)
        let s = LlmShape::llama1_13b();
        let oh = EngineOverhead::default();
        let r = e2e_latency(&g(), &s, GemmKind::Fp16, &oh, 1, 1024, 128, 0);
        assert!(r.self_decode_s > r.context_s);
    }
}
