//! Deterministic xorshift64* RNG — the project-wide randomness source
//! (no `rand` crate available offline).

/// xorshift64* PRNG.  Deterministic, seedable, fast; statistical quality
/// is plenty for test-data generation and workload sampling.
#[derive(Clone, Debug)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        XorShift { state: seed.wrapping_mul(2685821657736338717).max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = (self.next_u64() % (i as u64 + 1)) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next_u64() % xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift::new(7);
        let mut b = XorShift::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = XorShift::new(3);
        for _ in 0..1000 {
            let v = r.range(-5, 5);
            assert!((-5..5).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = XorShift::new(4);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift::new(5);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = XorShift::new(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
