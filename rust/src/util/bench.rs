//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage mirrors the bench binaries in `benches/`:
//! ```ignore
//! let mut b = Bencher::new("fastgemm m1024");
//! let res = b.run(|| { work(); });
//! println!("{}", res);
//! ```

use std::time::Instant;

use super::stats::Summary;

/// Result of one benchmark: timing summary in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
    pub std_s: f64,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:40} {:>10.3} ms/iter (p50 {:.3}, min {:.3}, sd {:.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.min_s * 1e3,
            self.std_s * 1e3,
            self.iters
        )
    }
}

/// Adaptive-iteration bencher: warms up, then measures until either
/// `max_iters` or `budget_s` of wall time is spent.
pub struct Bencher {
    name: String,
    pub warmup: usize,
    pub max_iters: usize,
    pub min_iters: usize,
    pub budget_s: f64,
}

impl Bencher {
    pub fn new(name: &str) -> Self {
        Bencher {
            name: name.to_string(),
            warmup: 1,
            max_iters: 50,
            min_iters: 3,
            budget_s: 2.0,
        }
    }

    pub fn with_budget(mut self, s: f64) -> Self {
        self.budget_s = s;
        self
    }

    pub fn with_iters(mut self, min: usize, max: usize) -> Self {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    pub fn run<F: FnMut()>(&mut self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut s = Summary::new();
        let start = Instant::now();
        loop {
            let t = Instant::now();
            f();
            s.add(t.elapsed().as_secs_f64());
            let done_budget = start.elapsed().as_secs_f64() > self.budget_s
                && s.len() >= self.min_iters;
            if s.len() >= self.max_iters || done_budget {
                break;
            }
        }
        BenchResult {
            name: self.name.clone(),
            iters: s.len(),
            mean_s: s.mean(),
            p50_s: s.p50(),
            min_s: s.min(),
            std_s: s.std(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bencher::new("noop").with_budget(0.05).with_iters(3, 10);
        let r = b.run(|| { std::hint::black_box(1 + 1); });
        assert!(r.iters >= 3 && r.iters <= 10);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s + 1e-12);
    }

    #[test]
    fn display_contains_name() {
        let mut b = Bencher::new("xyz").with_budget(0.01).with_iters(3, 3);
        let r = b.run(|| {});
        assert!(format!("{r}").contains("xyz"));
    }
}
