//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage mirrors the bench binaries in `benches/`:
//! ```ignore
//! let mut b = Bencher::new("fastgemm m1024");
//! let res = b.run(|| { work(); });
//! println!("{}", res);
//! ```

use std::time::Instant;

use super::stats::Summary;
use crate::formats::json::Json;

/// Result of one benchmark: timing summary in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
    pub std_s: f64,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:40} {:>10.3} ms/iter (p50 {:.3}, min {:.3}, sd {:.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.min_s * 1e3,
            self.std_s * 1e3,
            self.iters
        )
    }
}

/// Adaptive-iteration bencher: warms up, then measures until either
/// `max_iters` or `budget_s` of wall time is spent.
pub struct Bencher {
    name: String,
    pub warmup: usize,
    pub max_iters: usize,
    pub min_iters: usize,
    pub budget_s: f64,
}

impl Bencher {
    pub fn new(name: &str) -> Self {
        Bencher {
            name: name.to_string(),
            warmup: 1,
            max_iters: 50,
            min_iters: 3,
            budget_s: 2.0,
        }
    }

    pub fn with_budget(mut self, s: f64) -> Self {
        self.budget_s = s;
        self
    }

    pub fn with_iters(mut self, min: usize, max: usize) -> Self {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    pub fn run<F: FnMut()>(&mut self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut s = Summary::new();
        let start = Instant::now();
        loop {
            let t = Instant::now();
            f();
            s.add(t.elapsed().as_secs_f64());
            let done_budget = start.elapsed().as_secs_f64() > self.budget_s
                && s.len() >= self.min_iters;
            if s.len() >= self.max_iters || done_budget {
                break;
            }
        }
        BenchResult {
            name: self.name.clone(),
            iters: s.len(),
            mean_s: s.mean(),
            p50_s: s.p50(),
            min_s: s.min(),
            std_s: s.std(),
        }
    }
}

/// Merge benchmark records into a committed json trajectory file
/// (`BENCH_kernels.json` at the repo root): the file holds a json
/// ARRAY of flat records, each carrying a `"bench"` field naming the
/// bench binary section that produced it.  Re-running a bench replaces
/// ONLY its own section — records from other benches (and the file's
/// self-describing `"about"` record) survive, so `gemm_kernels` and
/// `hot_loop` can both write the same file in any order.
///
/// A missing or unparsable file degrades to an empty array rather than
/// erroring: the seed committed with the repo may be regenerated from
/// scratch on a fresh runner.
pub fn merge_bench_records(
    path: &str,
    bench: &str,
    records: &[Json],
) -> std::io::Result<()> {
    let mut all: Vec<Json> = match std::fs::read_to_string(path) {
        Ok(text) => Json::parse(&text)
            .ok()
            .and_then(|v| v.as_arr().map(|a| a.to_vec()))
            .unwrap_or_default(),
        Err(_) => Vec::new(),
    };
    all.retain(|r| r.get("bench").as_str() != Some(bench));
    all.extend(records.iter().cloned());
    // one record per line: stable-ish diffs when sections regenerate
    let mut out = String::from("[\n");
    for (i, r) in all.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&r.emit());
        if i + 1 < all.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]\n");
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_replaces_own_section_only() {
        let path = std::env::temp_dir().join(format!(
            "odyssey_bench_merge_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        let rec = |bench: &str, v: f64| {
            Json::obj(vec![
                ("bench", Json::Str(bench.into())),
                ("value", Json::Num(v)),
            ])
        };
        // missing file -> section written fresh
        let _ = std::fs::remove_file(&path);
        merge_bench_records(&path, "a", &[rec("a", 1.0)]).unwrap();
        // a second section appends without touching the first
        merge_bench_records(&path, "b", &[rec("b", 2.0)]).unwrap();
        // re-running the first section replaces only its own records
        merge_bench_records(&path, "a", &[rec("a", 3.0)]).unwrap();
        let v = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let val = |bench: &str| {
            arr.iter()
                .find(|r| r.get("bench").as_str() == Some(bench))
                .map(|r| r.get("value").as_f64().unwrap())
        };
        assert_eq!(val("a"), Some(3.0));
        assert_eq!(val("b"), Some(2.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn merge_tolerates_garbage_file() {
        let path = std::env::temp_dir().join(format!(
            "odyssey_bench_garbage_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, "not json at all").unwrap();
        merge_bench_records(
            &path,
            "x",
            &[Json::obj(vec![("bench", Json::Str("x".into()))])],
        )
        .unwrap();
        let v = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn runs_and_reports() {
        let mut b = Bencher::new("noop").with_budget(0.05).with_iters(3, 10);
        let r = b.run(|| { std::hint::black_box(1 + 1); });
        assert!(r.iters >= 3 && r.iters <= 10);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s + 1e-12);
    }

    #[test]
    fn display_contains_name() {
        let mut b = Bencher::new("xyz").with_budget(0.01).with_iters(3, 3);
        let r = b.run(|| {});
        assert!(format!("{r}").contains("xyz"));
    }
}
