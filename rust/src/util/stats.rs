//! Streaming statistics + percentile summaries for latency metrics.

/// Summary statistics over a sample of f64 observations.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.values.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / (self.values.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // NaN samples (e.g. the mean of an empty sub-summary folded
            // back in) must neither panic partial_cmp().unwrap() nor
            // land at the FRONT (total_cmp alone puts negative-sign
            // NaNs before -inf): order by (is_nan, total_cmp) so every
            // NaN sorts after every finite sample.
            self.values.sort_by(|a, b| {
                a.is_nan().cmp(&b.is_nan()).then(a.total_cmp(b))
            });
            self.sorted = true;
        }
    }

    /// Percentile via linear interpolation; `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.values.len();
        let rank = p / 100.0 * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.values[lo]
        } else {
            let w = rank - lo as f64;
            self.values[lo] * (1.0 - w) + self.values[hi] * w
        }
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// One-line human-readable report (all values interpreted as seconds).
    pub fn report_ms(&mut self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p99={:.3}ms min={:.3}ms max={:.3}ms",
            self.len(),
            self.mean() * 1e3,
            self.p50() * 1e3,
            self.p99() * 1e3,
            self.min() * 1e3,
            self.max() * 1e3
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.add(v);
        }
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for v in 0..101 {
            s.add(v as f64);
        }
        assert!((s.p50() - 50.0).abs() < 1e-9);
        assert!((s.percentile(0.0) - 0.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.p99() - 99.0).abs() < 1e-9);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.p50().is_nan());
    }

    #[test]
    fn nan_sample_does_not_panic() {
        // regression: a NaN latency sample (mean of an empty
        // sub-summary) used to panic percentile() via partial_cmp
        let mut s = Summary::new();
        s.add(2.0);
        s.add(Summary::new().mean()); // NaN
        s.add(-f64::NAN); // negative-sign NaN (total_cmp sorts it FIRST)
        s.add(1.0);
        let p0 = s.percentile(0.0);
        assert_eq!(p0, 1.0, "finite samples sort ahead of every NaN");
        assert!(s.percentile(100.0).is_nan(), "NaNs sort last");
        let _ = s.report_ms(); // must not panic either
    }

    #[test]
    fn interpolation() {
        let mut s = Summary::new();
        s.add(0.0);
        s.add(10.0);
        assert!((s.percentile(50.0) - 5.0).abs() < 1e-9);
        assert!((s.percentile(25.0) - 2.5).abs() < 1e-9);
    }
}
