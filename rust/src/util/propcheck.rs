//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! A `Prop` runs a closure over N generated cases from a seeded RNG and
//! reports the first failing seed so failures reproduce exactly:
//!
//! ```ignore
//! Prop::new("pack/unpack roundtrip").cases(200).check(|rng| {
//!     let q = random_int4(rng);
//!     assert_eq!(unpack(pack(&q)), q);
//! });
//! ```

use super::rng::XorShift;

pub struct Prop {
    name: &'static str,
    cases: usize,
    base_seed: u64,
}

impl Prop {
    pub fn new(name: &'static str) -> Self {
        Prop { name, cases: 100, base_seed: 0xC0FFEE }
    }

    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.base_seed = s;
        self
    }

    /// Run the property across `cases` seeds; panic with the failing seed
    /// on first failure.
    pub fn check<F: Fn(&mut XorShift) + std::panic::RefUnwindSafe>(
        &self,
        f: F,
    ) {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case as u64);
            let result = std::panic::catch_unwind(|| {
                let mut rng = XorShift::new(seed);
                f(&mut rng);
            });
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property '{}' failed at case {} (seed {:#x}): {}",
                    self.name, case, seed, msg
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        Prop::new("addition commutes").cases(50).check(|rng| {
            let a = rng.range(-100, 100);
            let b = rng.range(-100, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_reports_seed() {
        Prop::new("always fails").cases(5).check(|_rng| {
            panic!("always fails");
        });
    }
}
