//! Substrate utilities: logging, timing, statistics, deterministic RNG,
//! a thread pool, and a miniature property-testing harness.
//!
//! These exist because the build environment is fully offline: no tokio,
//! no criterion, no proptest, no rand.  Everything here is std-only.

pub mod bench;
pub mod log;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod threadpool;

pub use bench::{merge_bench_records, BenchResult, Bencher};
pub use log::{set_level, Level};
pub use propcheck::Prop;
pub use rng::XorShift;
pub use stats::Summary;
pub use threadpool::ThreadPool;

use std::time::Instant;

/// Wall-clock timer with human-readable reporting.
pub struct Timer {
    start: Instant,
    label: &'static str,
}

impl Timer {
    pub fn start(label: &'static str) -> Self {
        Timer { start: Instant::now(), label }
    }

    /// Elapsed seconds since construction.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Log the elapsed time at info level and return it.
    pub fn report(&self) -> f64 {
        let s = self.secs();
        crate::util::log::info(&format!("{}: {:.3}s", self.label, s));
        s
    }
}
