//! Tiny leveled logger (stderr).  `ODYSSEY_LOG=debug|info|warn|error`.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

/// Set the global log level.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Initialize from the ODYSSEY_LOG env var (default: info).
pub fn init_from_env() {
    let l = match std::env::var("ODYSSEY_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    set_level(l);
}

fn enabled(l: Level) -> bool {
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

fn emit(l: Level, msg: &str) {
    if enabled(l) {
        eprintln!("[{:5}] {}", format!("{:?}", l).to_lowercase(), msg);
    }
}

pub fn debug(msg: &str) {
    emit(Level::Debug, msg);
}

pub fn info(msg: &str) {
    emit(Level::Info, msg);
}

pub fn warn(msg: &str) {
    emit(Level::Warn, msg);
}

pub fn error(msg: &str) {
    emit(Level::Error, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn set_and_filter() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}
