//! Fixed-size thread pool over std::sync::mpsc (tokio is unavailable
//! offline).  Used by the HTTP server, data-parallel quantization, and
//! the `kernels::ParallelKernels` GEMM set (which holds one pool for
//! the process, sized once — see `kernels::dispatch`).

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

type Pending = (Mutex<usize>, Condvar);

/// Decrements the pending-job counter on drop, so a panicking job still
/// releases its slot and `join` cannot hang on a lost decrement.
struct PendingGuard<'a>(&'a Pending);

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        let (lock, cv) = self.0;
        let mut cnt = lock.lock().unwrap();
        *cnt -= 1;
        if *cnt == 0 {
            cv.notify_all();
        }
    }
}

/// A simple fixed-size worker pool.  Jobs run FIFO; `join` blocks until
/// all submitted jobs have completed (the pool stays usable afterwards).
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<Pending>,
}

impl ThreadPool {
    /// Pool of `n` workers; `n == 0` is clamped to 1 (a degenerate but
    /// valid pool) rather than panicking.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        let _slot = PendingGuard(&pending);
                        // keep the worker alive across a panicking job;
                        // par_map re-raises from the missing result
                        let _ = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(job),
                        );
                    }
                    Err(_) => break,
                }
            }));
        }
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Pool sized to the machine (cores, min 2).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .max(2);
        Self::new(n)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    fn execute_boxed(&self, job: Job) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(job)
            .expect("worker channel closed");
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.execute_boxed(Box::new(f));
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut cnt = lock.lock().unwrap();
        while *cnt > 0 {
            cnt = cv.wait(cnt).unwrap();
        }
    }

    /// Map `f` over `items` in parallel, preserving order.  An empty
    /// `items` returns an empty vec without touching the pool.
    ///
    /// Scoped: `f` and the items may borrow from the caller's stack —
    /// `join()` runs before this returns, so every borrow outlives every
    /// job.  If a job panics, the panic is re-raised here (after all
    /// other jobs have drained) rather than deadlocking `join`.
    pub fn par_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
        {
            let f_ref: &(dyn Fn(T) -> R + Sync) = &f;
            // usize-erased base pointer: each job writes only slot i,
            // and slots are disjoint, so no two jobs alias
            let res_base = results.as_mut_ptr() as usize;
            for (i, item) in items.into_iter().enumerate() {
                let job: Box<dyn FnOnce() + Send + '_> =
                    Box::new(move || {
                        let r = f_ref(item);
                        // SAFETY: i < n, slots are disjoint per job, and
                        // `join()` below keeps `results` alive and
                        // unobserved until every job has finished
                        unsafe {
                            *(res_base as *mut Option<R>).add(i) = Some(r);
                        }
                    });
                // SAFETY: lifetime erasure only — `join()` below blocks
                // until the job has run, so the borrows it captures
                // (f_ref, res_base's buffer) outlive it
                let job: Job = unsafe { std::mem::transmute(job) };
                self.execute_boxed(job);
            }
            self.join();
        }
        results
            .into_iter()
            .map(|r| r.expect("par_map worker panicked"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.par_map((0..50).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn zero_size_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.par_map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn par_map_empty_items() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.par_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
        pool.join(); // pool untouched and still healthy
    }

    #[test]
    fn par_map_borrows_from_caller() {
        // the scoped contract: closures may capture stack references
        let pool = ThreadPool::new(3);
        let base = vec![10i32, 20, 30, 40];
        let out =
            pool.par_map((0..4).collect::<Vec<usize>>(), |i| base[i] + 1);
        assert_eq!(out, vec![11, 21, 31, 41]);
    }

    #[test]
    fn panicking_job_does_not_hang_join() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.join(); // must return despite the panic
        // pool still works afterwards
        let out = pool.par_map(vec![1, 2], |x| x * 3);
        assert_eq!(out, vec![3, 6]);
    }

    #[test]
    fn par_map_reraises_worker_panic() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || pool.par_map(vec![0, 1], |x| if x == 1 { panic!() } else { x }),
        ));
        assert!(r.is_err(), "panic must surface to the caller");
    }

    #[test]
    fn join_without_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.join(); // must not hang
    }

    #[test]
    fn reusable_after_join() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicUsize::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&c);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join();
            assert_eq!(c.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }
}
