//! Fixed-size thread pool over std::sync::mpsc (tokio is unavailable
//! offline).  Used by the HTTP server and by data-parallel quantization.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple fixed-size worker pool.  Jobs run FIFO; `join` blocks until
/// all submitted jobs have completed (the pool stays usable afterwards).
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        job();
                        let (lock, cv) = &*pending;
                        let mut cnt = lock.lock().unwrap();
                        *cnt -= 1;
                        if *cnt == 0 {
                            cv.notify_all();
                        }
                    }
                    Err(_) => break,
                }
            }));
        }
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Pool sized to the machine (cores, min 2).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .max(2);
        Self::new(n)
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut cnt = lock.lock().unwrap();
        while *cnt > 0 {
            cnt = cv.wait(cnt).unwrap();
        }
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn par_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> = Arc::new(Mutex::new(
            items.iter().map(|_| None).collect(),
        ));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.execute(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.join();
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("results still shared"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.unwrap())
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.par_map((0..50).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn join_without_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.join(); // must not hang
    }

    #[test]
    fn reusable_after_join() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicUsize::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&c);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join();
            assert_eq!(c.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }
}
