//! Runtime integration: compile real AOT artifacts on the PJRT CPU
//! client and verify cross-kernel numerical contracts.

use odyssey::exp::latency::random_gemm_args;
use odyssey::quant::{pack, rtn, scale};
use odyssey::runtime::{literal_f32, literal_from_st, Runtime};
use odyssey::formats::safetensors::StTensor;
use odyssey::tensor::Tensor;

fn rt() -> Runtime {
    Runtime::new("artifacts").expect("run `make artifacts` first")
}

#[test]
fn manifest_loads_and_is_complete() {
    let rt = rt();
    assert!(rt.manifest.models.contains_key("tiny3m"));
    assert!(rt.manifest.group_size > 0);
    // every graph's HLO file exists
    for g in rt.manifest.graphs.values() {
        assert!(
            rt.manifest.hlo_path(g).exists(),
            "missing artifact {}",
            g.path
        );
    }
    // serving graphs present for every tiny3m variant
    for variant in
        ["fp", "w8a8", "w4a8_fast", "w4a8_group", "w4a8_asym", "w4a16"]
    {
        for stage in ["prefill", "decode"] {
            let name = rt.manifest.stage_graph("tiny3m", variant, stage, 4);
            assert!(rt.manifest.graphs.contains_key(&name), "{name}");
        }
    }
}

#[test]
fn gemm_graph_executes_with_valid_output() {
    let mut rt = rt();
    let gi = rt
        .manifest
        .gemm_graphs("cpu")
        .into_iter()
        .find(|g| g.variant == "w8a8" && g.m == 1)
        .expect("cpu w8a8 graph")
        .clone();
    let args = random_gemm_args(&gi.params).unwrap();
    let outs = rt.run_literals(&gi.name, &args).unwrap();
    assert_eq!(outs.len(), 1);
    let v = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(v.len(), gi.m * gi.n);
    assert!(v.iter().all(|x| x.is_finite()));
}

#[test]
fn fastgemm_graph_equals_w8a8_graph_times_16() {
    // FastGEMM contract on the REAL artifacts: feeding w8a8 with the
    // x16-unpacked weights and s_w/16 must reproduce fastgemm exactly.
    let mut rt = rt();
    let fast = rt
        .manifest
        .gemm_graphs("cpu")
        .into_iter()
        .find(|g| g.variant == "w4a8_fast" && g.m == 1 && g.n == 1024)
        .unwrap()
        .clone();
    let w8 = rt
        .manifest
        .gemm_graphs("cpu")
        .into_iter()
        .find(|g| {
            g.variant == "w8a8" && g.m == 1 && g.n == fast.n && g.k == fast.k
        })
        .unwrap()
        .clone();

    let (m, n, k) = (fast.m, fast.n, fast.k);
    // random int4 weights + activations
    let x = Tensor::randn(&[m, k], 11);
    let (xq, s_a) = scale::quant_act_per_token(&x);
    let wf = Tensor::randn(&[k, n], 12);
    let (q4, s_w) = rtn::rtn_per_channel(&wf, 4, None, None);
    let p = pack::pack_int4(&q4);
    let x16 = pack::unpack_x16(&p);

    let xq_l = literal_from_st(&StTensor::from_i8(&xq)).unwrap();
    let sa_l = literal_f32(&[m], &s_a).unwrap();

    let fast_out = rt
        .run_literals(
            &fast.name,
            &[
                xq_l.clone(),
                sa_l.clone(),
                literal_from_st(&StTensor::from_u8(&p)).unwrap(),
                literal_f32(&[n], &s_w).unwrap(),
            ],
        )
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();

    let s16: Vec<f32> = s_w.iter().map(|v| v / 16.0).collect();
    let w8_out = rt
        .run_literals(
            &w8.name,
            &[
                xq_l,
                sa_l,
                literal_from_st(&StTensor::from_i8(&x16)).unwrap(),
                literal_f32(&[n], &s16).unwrap(),
            ],
        )
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();

    let maxd = fast_out
        .iter()
        .zip(w8_out.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(maxd < 1e-4, "x16 contract violated: maxdiff {maxd}");
}

#[test]
fn wrong_arg_count_rejected() {
    let mut rt = rt();
    let gi = rt
        .manifest
        .gemm_graphs("cpu")
        .into_iter()
        .find(|g| g.variant == "fp" && g.m == 1)
        .unwrap()
        .clone();
    let mut args = random_gemm_args(&gi.params).unwrap();
    args.pop();
    assert!(rt.run_literals(&gi.name, &args).is_err());
}

#[test]
fn unknown_graph_rejected() {
    let mut rt = rt();
    assert!(rt.run_literals("nope_graph", &[]).is_err());
    assert!(rt.executable("nope_graph").is_err());
}

#[test]
fn executable_cache_reuses_compilation() {
    let mut rt = rt();
    let gi = rt
        .manifest
        .gemm_graphs("cpu")
        .into_iter()
        .find(|g| g.variant == "fp" && g.m == 1)
        .unwrap()
        .clone();
    rt.executable(&gi.name).unwrap();
    let n1 = rt.loaded_graphs();
    rt.executable(&gi.name).unwrap();
    assert_eq!(rt.loaded_graphs(), n1, "second call must hit the cache");
}
