//! Runtime integration on the native CPU backend: manifest completeness,
//! GEMM graph execution, and cross-kernel numerical contracts.
//!
//! Artifacts are synthesized on first use (`runtime::synth`) — no python
//! AOT pass required.  The same tests run against real AOT artifacts on
//! the pjrt backend by swapping `BackendKind`.

use odyssey::exp::eval::{load_corpus, Evaluator};
use odyssey::exp::latency::random_gemm_args;
use odyssey::formats::safetensors::StTensor;
use odyssey::model::{self, Checkpoint};
use odyssey::quant::{pack, rtn, scale, QuantRecipe};
use odyssey::runtime::{
    literal_f32, literal_from_st, literal_i32, synth, BackendKind, KvDtype,
    Runtime,
};
use odyssey::tensor::Tensor;

fn rt() -> Runtime {
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    Runtime::with_backend("artifacts", BackendKind::Native)
        .expect("native runtime")
}

#[test]
fn manifest_loads_and_is_complete() {
    let rt = rt();
    assert_eq!(rt.backend_name(), "native");
    assert!(rt.manifest.models.contains_key("tiny3m"));
    assert!(rt.manifest.group_size > 0);
    // every graph's HLO file exists
    for g in rt.manifest.graphs.values() {
        assert!(
            rt.manifest.hlo_path(g).exists(),
            "missing artifact {}",
            g.path
        );
    }
    // serving graphs present for every tiny3m variant
    for variant in
        ["fp", "w8a8", "w4a8_fast", "w4a8_group", "w4a8_asym", "w4a16"]
    {
        for stage in ["prefill", "decode"] {
            let name = rt.manifest.stage_graph("tiny3m", variant, stage, 4);
            assert!(rt.manifest.graphs.contains_key(&name), "{name}");
        }
    }
}

#[test]
fn gemm_graph_executes_with_valid_output() {
    let mut rt = rt();
    let gi = rt
        .manifest
        .gemm_graphs("cpu")
        .into_iter()
        .find(|g| g.variant == "w8a8" && g.m == 1)
        .expect("cpu w8a8 graph")
        .clone();
    let args = random_gemm_args(&gi.params).unwrap();
    let outs = rt.run_literals(&gi.name, &args).unwrap();
    assert_eq!(outs.len(), 1);
    let v = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(v.len(), gi.m * gi.n);
    assert!(v.iter().all(|x| x.is_finite()));
}

#[test]
fn fastgemm_graph_equals_w8a8_graph_times_16() {
    // FastGEMM contract through the runtime: feeding w8a8 with the
    // x16-unpacked weights and s_w/16 must reproduce fastgemm exactly.
    let mut rt = rt();
    let fast = rt
        .manifest
        .gemm_graphs("cpu")
        .into_iter()
        .find(|g| g.variant == "w4a8_fast" && g.m == 1 && g.n == 1024)
        .unwrap()
        .clone();
    let w8 = rt
        .manifest
        .gemm_graphs("cpu")
        .into_iter()
        .find(|g| {
            g.variant == "w8a8" && g.m == 1 && g.n == fast.n && g.k == fast.k
        })
        .unwrap()
        .clone();

    let (m, n, k) = (fast.m, fast.n, fast.k);
    // random int4 weights + activations
    let x = Tensor::randn(&[m, k], 11);
    let (xq, s_a) = scale::quant_act_per_token(&x).unwrap();
    let wf = Tensor::randn(&[k, n], 12);
    let (q4, s_w) = rtn::rtn_per_channel(&wf, 4, None, None);
    let p = pack::pack_int4(&q4);
    let x16 = pack::unpack_x16(&p);

    let xq_l = literal_from_st(&StTensor::from_i8(&xq)).unwrap();
    let sa_l = literal_f32(&[m], &s_a).unwrap();

    let fast_out = rt
        .run_literals(
            &fast.name,
            &[
                xq_l.clone(),
                sa_l.clone(),
                literal_from_st(&StTensor::from_u8(&p)).unwrap(),
                literal_f32(&[n], &s_w).unwrap(),
            ],
        )
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();

    let s16: Vec<f32> = s_w.iter().map(|v| v / 16.0).collect();
    let w8_out = rt
        .run_literals(
            &w8.name,
            &[
                xq_l,
                sa_l,
                literal_from_st(&StTensor::from_i8(&x16)).unwrap(),
                literal_f32(&[n], &s16).unwrap(),
            ],
        )
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();

    let maxd = fast_out
        .iter()
        .zip(w8_out.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(maxd < 1e-4, "x16 contract violated: maxdiff {maxd}");
}

#[test]
fn prefill_graph_serves_w4a8_fast_weights() {
    // quantize the synthetic checkpoint with the FastGEMM layout and
    // push it through the b=1 prefill graph on the native backend
    let mut rt = rt();
    let info = rt.manifest.model("tiny3m").unwrap().clone();
    let ckpt = Checkpoint::load(&rt.manifest, "tiny3m").unwrap();
    let qw = model::quantize_checkpoint(
        &ckpt,
        None,
        &QuantRecipe::vanilla_w4(),
        "w4a8_fast",
        rt.manifest.group_size,
    )
    .unwrap();
    let graph = rt.manifest.stage_graph("tiny3m", "w4a8_fast", "prefill", 1);
    let gi = rt.manifest.graph(&graph).unwrap().clone();
    let (b, s) = (gi.batch, gi.seq);

    let mut tokens = vec![0i32; b * s];
    for (i, t) in tokens.iter_mut().enumerate().take(10) {
        *t = 3 + i as i32;
    }
    let mut args =
        vec![literal_i32(&[b, s], &tokens).unwrap(),
             literal_i32(&[b], &[10]).unwrap()];
    for t in &qw.tensors {
        args.push(literal_from_st(t).unwrap());
    }
    let outs = rt.run_literals(&graph, &args).unwrap();
    assert_eq!(outs.len(), 1 + 2 * info.n_layers);
    let logits = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(logits.len(), b * s * info.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));
    // KV caches come back in device layout, padded to max_seq
    assert_eq!(
        outs[1].shape(),
        &[b, info.n_heads, info.max_seq, info.head_dim]
    );
}

#[test]
fn wrong_arg_count_rejected() {
    let mut rt = rt();
    let gi = rt
        .manifest
        .gemm_graphs("cpu")
        .into_iter()
        .find(|g| g.variant == "fp" && g.m == 1)
        .unwrap()
        .clone();
    let mut args = random_gemm_args(&gi.params).unwrap();
    args.pop();
    assert!(rt.run_literals(&gi.name, &args).is_err());
}

#[test]
fn unknown_graph_rejected() {
    let mut rt = rt();
    assert!(rt.run_literals("nope_graph", &[]).is_err());
    assert!(rt.executable("nope_graph").is_err());
}

#[test]
fn executable_cache_reuses_compilation() {
    let mut rt = rt();
    let gi = rt
        .manifest
        .gemm_graphs("cpu")
        .into_iter()
        .find(|g| g.variant == "fp" && g.m == 1)
        .unwrap()
        .clone();
    rt.executable(&gi.name).unwrap();
    let n1 = rt.loaded_graphs();
    rt.executable(&gi.name).unwrap();
    assert_eq!(rt.loaded_graphs(), n1, "second call must hit the cache");
}

#[test]
fn int8_kv_decode_perplexity_stays_within_documented_bound() {
    // The quantized-KV quality gate.  Prefill-graph perplexity cannot
    // see KV storage (attention runs off fresh f32 activations), so
    // the comparison is teacher-forced DECODE perplexity: every
    // prediction reads its whole history back out of the paged pool.
    // fp32 pool vs int8 pool on the same held-out windows — the delta
    // is pure KV-quantization noise and must stay inside the 5%
    // relative bound the README documents.
    let mut ev = Evaluator::with_runtime(
        rt(),
        "tiny3m",
        "fp",
        &QuantRecipe::vanilla_w4(),
    )
    .expect("evaluator");
    let corpus = load_corpus("artifacts", "val").expect("val corpus");
    // 24-position windows span two 16-position blocks per stream, so
    // history reads cross a block boundary; 8 windows = two decode
    // batches keeps the runtime test-sized.
    let ppl_f = ev
        .decode_perplexity(&corpus, 24, 8, KvDtype::F32)
        .expect("fp32 decode perplexity");
    let ppl_q = ev
        .decode_perplexity(&corpus, 24, 8, KvDtype::Int8)
        .expect("int8 decode perplexity");
    assert!(
        ppl_f.is_finite() && ppl_f > 1.0,
        "fp32 decode perplexity must be a sane positive value, got \
         {ppl_f}"
    );
    assert!(
        ppl_q.is_finite() && ppl_q > 1.0,
        "int8 decode perplexity must be finite, got {ppl_q}"
    );
    let delta = (ppl_q - ppl_f).abs() / ppl_f;
    assert!(
        delta < 0.05,
        "int8 KV moved decode perplexity {ppl_f:.4} -> {ppl_q:.4} \
         ({:.2}% relative, documented bound is 5%)",
        delta * 100.0
    );
}
