//! Cross-module property tests (mini-propcheck harness; seeds reported
//! on failure).  Pure CPU — no artifacts needed.

use odyssey::coordinator::kv::KvState;
use odyssey::coordinator::queue::{Admit, RequestQueue};
use odyssey::coordinator::request::{GenParams, Request};
use odyssey::formats::json::Json;
use odyssey::formats::safetensors::{SafeTensors, StTensor};
use odyssey::quant::{gptq, lwc, pack, rtn, scale, GptqConfig};
use odyssey::tensor::Tensor;
use odyssey::util::propcheck::Prop;
use odyssey::util::XorShift;

// ---------------------------------------------------------------- quant

/// The FastGEMM identity at the integer level: for any int8 activations
/// and int4 weights, acc(x, 16·w) / 16 == acc(x, w) EXACTLY (s32 math).
#[test]
fn prop_fastgemm_x16_identity() {
    Prop::new("fastgemm x16 identity").cases(200).check(|rng| {
        let k = 2 * (1 + (rng.next_u64() % 32) as usize);
        let n = 1 + (rng.next_u64() % 8) as usize;
        let q: Vec<i8> = (0..k * n).map(|_| rng.range(-8, 8) as i8).collect();
        let x: Vec<i8> =
            (0..k).map(|_| rng.range(-127, 128) as i8).collect();
        let qt = Tensor::from_vec(&[k, n], q);
        let p = pack::pack_int4(&qt);
        let w16 = pack::unpack_x16(&p);
        for j in 0..n {
            let mut acc: i32 = 0;
            let mut acc16: i32 = 0;
            for i in 0..k {
                acc += x[i] as i32 * qt.at2(i, j) as i32;
                acc16 += x[i] as i32 * w16.at2(i, j) as i32;
            }
            assert_eq!(acc16, acc * 16, "x16 accumulate must be exact");
            assert_eq!(acc16 / 16, acc);
        }
    });
}

#[test]
fn prop_lwc_at_least_as_good_as_vanilla() {
    Prop::new("lwc >= vanilla").cases(25).check(|rng| {
        let k = 16 + (rng.next_u64() % 64) as usize;
        let n = 1 + (rng.next_u64() % 6) as usize;
        let w = Tensor::randn(&[k, n], rng.next_u64());
        let r = lwc::lwc(&w, 4);
        for j in 0..n {
            assert!(r.mse[j] <= r.mse_vanilla[j] + 1e-15);
        }
    });
}

#[test]
fn prop_gptq_never_worse_than_rtn_on_calib_objective() {
    Prop::new("gptq <= rtn output-mse").cases(10).check(|rng| {
        let (k, n, t) = (24, 8, 192);
        let w = Tensor::randn(&[k, n], rng.next_u64());
        let mut x = Tensor::randn(&[t, k], rng.next_u64());
        // correlated channels (what GPTQ exploits)
        for i in 0..t {
            let base = x.at2(i, 0);
            for j in 1..4 {
                let v = 0.7 * base + 0.3 * x.at2(i, j);
                x.set2(i, j, v);
            }
        }
        let xt = x.transpose();
        let h = xt.matmul(&x).map(|v| 2.0 * v / t as f32);
        let res =
            gptq::gptq_quantize(&w, &h, &GptqConfig::default(), None)
                .unwrap();
        let w_g = rtn::dequant_per_channel(&res.q, &res.scales);
        let (qr, sr) = rtn::rtn_per_channel(&w, 4, None, None);
        let w_r = rtn::dequant_per_channel(&qr, &sr);
        let e_g = gptq::layer_output_mse(&x, &w, &w_g);
        let e_r = gptq::layer_output_mse(&x, &w, &w_r);
        assert!(
            e_g <= e_r * 1.001,
            "gptq {e_g} must not lose to rtn {e_r}"
        );
    });
}

#[test]
fn prop_act_quant_scales_bound_error() {
    Prop::new("act quant error bound").cases(50).check(|rng| {
        let m = 1 + (rng.next_u64() % 6) as usize;
        let k = 2 + (rng.next_u64() % 48) as usize;
        let x = Tensor::randn(&[m, k], rng.next_u64());
        let (q, s) = scale::quant_act_per_token(&x);
        for i in 0..m {
            for j in 0..k {
                let deq = q.at2(i, j) as f32 * s[i];
                assert!((deq - x.at2(i, j)).abs() <= 0.5 * s[i] + 1e-6);
            }
        }
    });
}

// ---------------------------------------------------------- coordinator

#[test]
fn prop_kv_slots_never_double_allocate() {
    Prop::new("kv slot model").cases(50).check(|rng| {
        let b = 2 + (rng.next_u64() % 6) as usize;
        let mut kv = KvState::new(b, 2, 2, 16, 4);
        let mut live: Vec<usize> = Vec::new();
        for step in 0..100u64 {
            if rng.next_f64() < 0.5 && kv.free_slots() > 0 {
                let slot = kv.alloc(step).unwrap();
                assert!(
                    !live.contains(&slot),
                    "slot {slot} double-allocated"
                );
                live.push(slot);
            } else if !live.is_empty() {
                let idx = (rng.next_u64() % live.len() as u64) as usize;
                let slot = live.swap_remove(idx);
                kv.free(slot);
            }
            assert_eq!(kv.free_slots(), b - live.len());
        }
    });
}

#[test]
fn prop_queue_fifo_and_conservation() {
    Prop::new("queue conservation").cases(50).check(|rng| {
        let cap = 4 + (rng.next_u64() % 12) as usize;
        let mut q = RequestQueue::new(cap);
        let mut next_id = 0u64;
        let mut expected: std::collections::VecDeque<u64> =
            Default::default();
        let mut popped: Vec<u64> = Vec::new();
        for _ in 0..200 {
            if rng.next_f64() < 0.6 {
                let r = Request::new(next_id, vec![1; 4],
                                     GenParams::default());
                if q.push(r) == Admit::Accepted {
                    expected.push_back(next_id);
                }
                next_id += 1;
            } else {
                let n = 1 + (rng.next_u64() % 3) as usize;
                let (batch, rej) = q.pop_batch(n, 100);
                assert!(rej.is_empty());
                for r in batch {
                    let want = expected.pop_front().unwrap();
                    assert_eq!(r.id, want, "FIFO violated");
                    popped.push(r.id);
                }
            }
            assert!(q.len() <= cap);
        }
        assert_eq!(q.len(), expected.len());
    });
}

// --------------------------------------------------------------- formats

fn random_json(rng: &mut XorShift, depth: usize) -> Json {
    match if depth == 0 { rng.next_u64() % 4 } else { rng.next_u64() % 6 } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
        3 => {
            let n = rng.next_u64() % 8;
            let n_special = (rng.next_u64() % 4) as usize;
            let mut s: String = (0..n)
                .map(|i| char::from(b'a' + ((rng.next_u64() + i) % 26) as u8))
                .collect();
            s.extend(['\\', '"', '\n'].into_iter().take(n_special));
            Json::Str(s)
        }
        4 => Json::Arr(
            (0..rng.next_u64() % 4)
                .map(|_| random_json(rng, depth - 1))
                .collect(),
        ),
        _ => Json::Obj(
            (0..rng.next_u64() % 4)
                .map(|i| {
                    (format!("k{i}"), random_json(rng, depth - 1))
                })
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    Prop::new("json emit/parse roundtrip").cases(200).check(|rng| {
        let v = random_json(rng, 3);
        let text = v.emit();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("parse failed on {text}: {e}"));
        assert_eq!(back, v, "roundtrip mismatch for {text}");
    });
}

#[test]
fn prop_safetensors_roundtrip() {
    Prop::new("safetensors roundtrip").cases(50).check(|rng| {
        let mut st = SafeTensors::new();
        let n_tensors = 1 + rng.next_u64() % 5;
        for i in 0..n_tensors {
            let rows = 1 + (rng.next_u64() % 8) as usize;
            let cols = 1 + (rng.next_u64() % 8) as usize;
            match rng.next_u64() % 3 {
                0 => st.insert(
                    &format!("t{i}"),
                    StTensor::from_f32(&Tensor::randn(
                        &[rows, cols],
                        rng.next_u64(),
                    )),
                ),
                1 => st.insert(
                    &format!("t{i}"),
                    StTensor::from_i8(&Tensor::from_vec(
                        &[rows * cols],
                        (0..rows * cols)
                            .map(|_| rng.range(-128, 128) as i8)
                            .collect(),
                    )),
                ),
                _ => st.insert(
                    &format!("t{i}"),
                    StTensor::from_i32(&Tensor::from_vec(
                        &[rows, cols],
                        (0..rows * cols)
                            .map(|_| rng.range(-1000, 1000) as i32)
                            .collect(),
                    )),
                ),
            }
        }
        let bytes = st.to_bytes();
        let back = SafeTensors::from_bytes(&bytes).unwrap();
        assert_eq!(back.len(), st.len());
        for name in st.names() {
            let a = st.get(name).unwrap();
            let b = back.get(name).unwrap();
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.bytes, b.bytes);
        }
    });
}

// ------------------------------------------------------------- corrupted

#[test]
fn corrupted_safetensors_rejected_not_panicking() {
    Prop::new("safetensors fuzz").cases(100).check(|rng| {
        let mut st = SafeTensors::new();
        st.insert(
            "x",
            StTensor::from_f32(&Tensor::randn(&[4, 4], 1)),
        );
        let mut bytes = st.to_bytes();
        // flip random bytes: must either parse or error, never panic
        for _ in 0..3 {
            let i = (rng.next_u64() % bytes.len() as u64) as usize;
            bytes[i] ^= (rng.next_u64() & 0xFF) as u8;
        }
        let _ = SafeTensors::from_bytes(&bytes);
    });
}

#[test]
fn corrupted_json_rejected_not_panicking() {
    Prop::new("json fuzz").cases(200).check(|rng| {
        let src = r#"{"a": [1, 2, {"b": "str"}], "c": -2.5e3}"#;
        let mut bytes = src.as_bytes().to_vec();
        for _ in 0..2 {
            let i = (rng.next_u64() % bytes.len() as u64) as usize;
            bytes[i] = (rng.next_u64() % 128) as u8;
        }
        if let Ok(text) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(text); // must not panic
        }
    });
}

// ------------------------------------------- native backend interop

/// The engine-path interop contract at the tiny3m weight shapes: for
/// every int4 nibble value, running the packed weights through the
/// native FastGEMM kernel (`unpack_x16` + /16 dequant epilogue) equals
/// the vanilla route (`unpack_int4` to true int4 values, then the plain
/// per-channel epilogue) BIT-EXACTLY.
#[test]
fn prop_fastgemm_epilogue_matches_unpacked_route_bit_exact() {
    use odyssey::runtime::native::{gemm_w4a8_fast, gemm_w8a8};

    // (K, N) pairs used by the tiny3m matrices: attention, gate/up, down
    let shapes = [(256usize, 256usize), (256, 768), (768, 256)];
    Prop::new("fastgemm epilogue interop").cases(3).check(|rng| {
        for &(k, n) in &shapes {
            let m = 2;
            let x = Tensor::randn(&[m, k], rng.next_u64());
            let (xq, s_a) = scale::quant_act_per_token(&x);
            // int4 weights covering ALL 16 nibble values: first rows
            // sweep -8..=7 in every column, the rest are random
            let mut q = Tensor::<i8>::zeros(&[k, n]);
            for i in 0..k {
                for j in 0..n {
                    let v = if i < 16 {
                        i as i32 - 8
                    } else {
                        rng.range(-8, 8) as i32
                    };
                    q.set2(i, j, v as i8);
                }
            }
            let s_w: Vec<f32> =
                (0..n).map(|_| 0.01 + rng.next_f32() * 0.05).collect();
            let p = pack::pack_int4(&q);

            // FastGEMM route: x16 weights, s_w/16 epilogue (inside)
            let fast = gemm_w4a8_fast(&xq, &s_a, &p, &s_w);
            // vanilla route: true int4 values + plain epilogue
            let w4 = pack::unpack_int4(&p);
            assert_eq!(w4, q, "unpack must invert pack");
            let vanilla = gemm_w8a8(&xq, &s_a, &w4, &s_w);

            assert_eq!(
                fast.shape(),
                vanilla.shape(),
                "shape mismatch at ({k},{n})"
            );
            for (i, (a, b)) in fast
                .data()
                .iter()
                .zip(vanilla.data().iter())
                .enumerate()
            {
                assert!(
                    a == b,
                    "({k},{n})[{i}]: fast {a} != vanilla {b} \
                     (must be bit-exact)"
                );
            }
        }
    });
}
