//! Cross-module property tests (mini-propcheck harness; seeds reported
//! on failure).  Pure CPU; the staged-execution parity section
//! synthesizes the tiny3m artifact set on first use.

use odyssey::coordinator::kv::KvState;
use odyssey::coordinator::queue::{Admit, RequestQueue};
use odyssey::coordinator::request::{GenParams, Request};
use odyssey::exp::latency::random_gemm_args_with;
use odyssey::formats::config::ModelInfo;
use odyssey::formats::json::Json;
use odyssey::formats::safetensors::{SafeTensors, StTensor};
use odyssey::model::{self, Checkpoint};
use odyssey::quant::{gptq, lwc, pack, rtn, scale, GptqConfig, QuantRecipe};
use odyssey::runtime::{self, synth, BackendKind, Runtime};
use odyssey::tensor::Tensor;
use odyssey::util::propcheck::Prop;
use odyssey::util::XorShift;

// ---------------------------------------------------------------- quant

/// The FastGEMM identity at the integer level: for any int8 activations
/// and int4 weights, acc(x, 16·w) / 16 == acc(x, w) EXACTLY (s32 math).
#[test]
fn prop_fastgemm_x16_identity() {
    Prop::new("fastgemm x16 identity").cases(200).check(|rng| {
        let k = 2 * (1 + (rng.next_u64() % 32) as usize);
        let n = 1 + (rng.next_u64() % 8) as usize;
        let q: Vec<i8> = (0..k * n).map(|_| rng.range(-8, 8) as i8).collect();
        let x: Vec<i8> =
            (0..k).map(|_| rng.range(-127, 128) as i8).collect();
        let qt = Tensor::from_vec(&[k, n], q);
        let p = pack::pack_int4(&qt);
        let w16 = pack::unpack_x16(&p);
        for j in 0..n {
            let mut acc: i32 = 0;
            let mut acc16: i32 = 0;
            for i in 0..k {
                acc += x[i] as i32 * qt.at2(i, j) as i32;
                acc16 += x[i] as i32 * w16.at2(i, j) as i32;
            }
            assert_eq!(acc16, acc * 16, "x16 accumulate must be exact");
            assert_eq!(acc16 / 16, acc);
        }
    });
}

#[test]
fn prop_lwc_at_least_as_good_as_vanilla() {
    Prop::new("lwc >= vanilla").cases(25).check(|rng| {
        let k = 16 + (rng.next_u64() % 64) as usize;
        let n = 1 + (rng.next_u64() % 6) as usize;
        let w = Tensor::randn(&[k, n], rng.next_u64());
        let r = lwc::lwc(&w, 4);
        for j in 0..n {
            assert!(r.mse[j] <= r.mse_vanilla[j] + 1e-15);
        }
    });
}

#[test]
fn prop_gptq_never_worse_than_rtn_on_calib_objective() {
    Prop::new("gptq <= rtn output-mse").cases(10).check(|rng| {
        let (k, n, t) = (24, 8, 192);
        let w = Tensor::randn(&[k, n], rng.next_u64());
        let mut x = Tensor::randn(&[t, k], rng.next_u64());
        // correlated channels (what GPTQ exploits)
        for i in 0..t {
            let base = x.at2(i, 0);
            for j in 1..4 {
                let v = 0.7 * base + 0.3 * x.at2(i, j);
                x.set2(i, j, v);
            }
        }
        let xt = x.transpose();
        let h = xt.matmul(&x).map(|v| 2.0 * v / t as f32);
        let res =
            gptq::gptq_quantize(&w, &h, &GptqConfig::default(), None)
                .unwrap();
        let w_g = rtn::dequant_per_channel(&res.q, &res.scales);
        let (qr, sr) = rtn::rtn_per_channel(&w, 4, None, None);
        let w_r = rtn::dequant_per_channel(&qr, &sr);
        let e_g = gptq::layer_output_mse(&x, &w, &w_g);
        let e_r = gptq::layer_output_mse(&x, &w, &w_r);
        assert!(
            e_g <= e_r * 1.001,
            "gptq {e_g} must not lose to rtn {e_r}"
        );
    });
}

#[test]
fn prop_act_quant_scales_bound_error() {
    Prop::new("act quant error bound").cases(50).check(|rng| {
        let m = 1 + (rng.next_u64() % 6) as usize;
        let k = 2 + (rng.next_u64() % 48) as usize;
        let x = Tensor::randn(&[m, k], rng.next_u64());
        let (q, s) = scale::quant_act_per_token(&x);
        for i in 0..m {
            for j in 0..k {
                let deq = q.at2(i, j) as f32 * s[i];
                assert!((deq - x.at2(i, j)).abs() <= 0.5 * s[i] + 1e-6);
            }
        }
    });
}

// ---------------------------------------------------------- coordinator

#[test]
fn prop_kv_slots_never_double_allocate() {
    Prop::new("kv slot model").cases(50).check(|rng| {
        let b = 2 + (rng.next_u64() % 6) as usize;
        let mut kv = KvState::new(b, 2, 2, 16, 4);
        let mut live: Vec<usize> = Vec::new();
        for step in 0..100u64 {
            if rng.next_f64() < 0.5 && kv.free_slots() > 0 {
                let slot = kv.alloc(step).unwrap();
                assert!(
                    !live.contains(&slot),
                    "slot {slot} double-allocated"
                );
                live.push(slot);
            } else if !live.is_empty() {
                let idx = (rng.next_u64() % live.len() as u64) as usize;
                let slot = live.swap_remove(idx);
                kv.free(slot);
            }
            assert_eq!(kv.free_slots(), b - live.len());
        }
    });
}

#[test]
fn prop_queue_fifo_and_conservation() {
    Prop::new("queue conservation").cases(50).check(|rng| {
        let cap = 4 + (rng.next_u64() % 12) as usize;
        let mut q = RequestQueue::new(cap);
        let mut next_id = 0u64;
        let mut expected: std::collections::VecDeque<u64> =
            Default::default();
        let mut popped: Vec<u64> = Vec::new();
        for _ in 0..200 {
            if rng.next_f64() < 0.6 {
                let r = Request::new(next_id, vec![1; 4],
                                     GenParams::default());
                if q.push(r) == Admit::Accepted {
                    expected.push_back(next_id);
                }
                next_id += 1;
            } else {
                let n = 1 + (rng.next_u64() % 3) as usize;
                let (batch, rej) = q.pop_batch(n, 100);
                assert!(rej.is_empty());
                for r in batch {
                    let want = expected.pop_front().unwrap();
                    assert_eq!(r.id, want, "FIFO violated");
                    popped.push(r.id);
                }
            }
            assert!(q.len() <= cap);
        }
        assert_eq!(q.len(), expected.len());
    });
}

// --------------------------------------------------------------- formats

fn random_json(rng: &mut XorShift, depth: usize) -> Json {
    match if depth == 0 { rng.next_u64() % 4 } else { rng.next_u64() % 6 } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
        3 => {
            let n = rng.next_u64() % 8;
            let n_special = (rng.next_u64() % 4) as usize;
            let mut s: String = (0..n)
                .map(|i| char::from(b'a' + ((rng.next_u64() + i) % 26) as u8))
                .collect();
            s.extend(['\\', '"', '\n'].into_iter().take(n_special));
            Json::Str(s)
        }
        4 => Json::Arr(
            (0..rng.next_u64() % 4)
                .map(|_| random_json(rng, depth - 1))
                .collect(),
        ),
        _ => Json::Obj(
            (0..rng.next_u64() % 4)
                .map(|i| {
                    (format!("k{i}"), random_json(rng, depth - 1))
                })
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    Prop::new("json emit/parse roundtrip").cases(200).check(|rng| {
        let v = random_json(rng, 3);
        let text = v.emit();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("parse failed on {text}: {e}"));
        assert_eq!(back, v, "roundtrip mismatch for {text}");
    });
}

#[test]
fn prop_safetensors_roundtrip() {
    Prop::new("safetensors roundtrip").cases(50).check(|rng| {
        let mut st = SafeTensors::new();
        let n_tensors = 1 + rng.next_u64() % 5;
        for i in 0..n_tensors {
            let rows = 1 + (rng.next_u64() % 8) as usize;
            let cols = 1 + (rng.next_u64() % 8) as usize;
            match rng.next_u64() % 3 {
                0 => st.insert(
                    &format!("t{i}"),
                    StTensor::from_f32(&Tensor::randn(
                        &[rows, cols],
                        rng.next_u64(),
                    )),
                ),
                1 => st.insert(
                    &format!("t{i}"),
                    StTensor::from_i8(&Tensor::from_vec(
                        &[rows * cols],
                        (0..rows * cols)
                            .map(|_| rng.range(-128, 128) as i8)
                            .collect(),
                    )),
                ),
                _ => st.insert(
                    &format!("t{i}"),
                    StTensor::from_i32(&Tensor::from_vec(
                        &[rows, cols],
                        (0..rows * cols)
                            .map(|_| rng.range(-1000, 1000) as i32)
                            .collect(),
                    )),
                ),
            }
        }
        let bytes = st.to_bytes();
        let back = SafeTensors::from_bytes(&bytes).unwrap();
        assert_eq!(back.len(), st.len());
        for name in st.names() {
            let a = st.get(name).unwrap();
            let b = back.get(name).unwrap();
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.bytes, b.bytes);
        }
    });
}

// ------------------------------------------------------------- corrupted

#[test]
fn corrupted_safetensors_rejected_not_panicking() {
    Prop::new("safetensors fuzz").cases(100).check(|rng| {
        let mut st = SafeTensors::new();
        st.insert(
            "x",
            StTensor::from_f32(&Tensor::randn(&[4, 4], 1)),
        );
        let mut bytes = st.to_bytes();
        // flip random bytes: must either parse or error, never panic
        for _ in 0..3 {
            let i = (rng.next_u64() % bytes.len() as u64) as usize;
            bytes[i] ^= (rng.next_u64() & 0xFF) as u8;
        }
        let _ = SafeTensors::from_bytes(&bytes);
    });
}

#[test]
fn corrupted_json_rejected_not_panicking() {
    Prop::new("json fuzz").cases(200).check(|rng| {
        let src = r#"{"a": [1, 2, {"b": "str"}], "c": -2.5e3}"#;
        let mut bytes = src.as_bytes().to_vec();
        for _ in 0..2 {
            let i = (rng.next_u64() % bytes.len() as u64) as usize;
            bytes[i] = (rng.next_u64() % 128) as u8;
        }
        if let Ok(text) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(text); // must not panic
        }
    });
}

// ------------------------------------- staged execution parity (tentpole)

/// Random tiny3m-shaped checkpoint (weights drawn fresh per case, so
/// the parity property ranges over graphs, not one fixed weight set).
fn random_checkpoint(info: &ModelInfo, rng: &mut XorShift) -> Checkpoint {
    let (d, f, v) = (info.d_model, info.d_ff, info.vocab);
    let mut tensors = std::collections::BTreeMap::new();
    for name in model::weight_names(info) {
        let leaf = name.rsplit('.').next().unwrap();
        let t = match leaf {
            "attn_norm" | "mlp_norm" | "norm_f" => {
                Tensor::randn(&[d], rng.next_u64()).map(|x| 1.0 + 0.05 * x)
            }
            "wq" | "wk" | "wv" | "wo" => Tensor::randn(&[d, d], rng.next_u64())
                .map(|x| x / (d as f32).sqrt()),
            "w_gate" | "w_up" => Tensor::randn(&[d, f], rng.next_u64())
                .map(|x| x / (d as f32).sqrt()),
            "w_down" => Tensor::randn(&[f, d], rng.next_u64())
                .map(|x| x / (f as f32).sqrt()),
            "embed" => {
                Tensor::randn(&[v, d], rng.next_u64()).map(|x| 0.02 * x)
            }
            "lm_head" => Tensor::randn(&[d, v], rng.next_u64())
                .map(|x| x / (d as f32).sqrt()),
            other => panic!("unexpected weight leaf {other}"),
        };
        tensors.insert(name, t);
    }
    Checkpoint { info: info.clone(), tensors }
}

/// `execute_staged` must be BIT-IDENTICAL to `execute` on the serving
/// graphs for the fp-sim, W8A8, and W4A8-fast paths — staging moves the
/// weight parse (including the SINT4toS8 x16 unpack) out of the step,
/// it must not change a single output bit.
#[test]
fn prop_staged_serving_graphs_bit_identical_to_unstaged() {
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    Prop::new("staged == unstaged (serving)").cases(2).check(|rng| {
        let mut rt =
            Runtime::with_backend("artifacts", BackendKind::Native).unwrap();
        let info = rt.manifest.model("tiny3m").unwrap().clone();
        let group = rt.manifest.group_size;
        for variant in ["fp", "w8a8", "w4a8_fast"] {
            let ckpt = random_checkpoint(&info, rng);
            let qw = model::quantize_checkpoint(
                &ckpt,
                None,
                &QuantRecipe::vanilla_w4(),
                variant,
                group,
            )
            .unwrap();
            let weights: Vec<runtime::Literal> = qw
                .tensors
                .iter()
                .map(|t| runtime::literal_from_st(t).unwrap())
                .collect();
            let pairs: Vec<(&str, &runtime::Literal)> = qw
                .names
                .iter()
                .map(String::as_str)
                .zip(weights.iter())
                .collect();

            // ---- prefill b=1: random prompt
            let graph = format!("tiny3m_{variant}_prefill_b1");
            let gi = rt.manifest.graph(&graph).unwrap().clone();
            let (b, s) = (gi.batch, gi.seq);
            let plen = 4 + (rng.next_u64() % 8) as usize;
            let mut tokens = vec![0i32; b * s];
            for t in tokens.iter_mut().take(plen) {
                *t = rng.range(3, info.vocab as i64 - 1) as i32;
            }
            let tok = runtime::literal_i32(&[b, s], &tokens).unwrap();
            let len =
                runtime::literal_i32(&[b], &[plen as i32]).unwrap();
            let mut full: Vec<&runtime::Literal> = vec![&tok, &len];
            full.extend(weights.iter());
            let unstaged = rt.run_literal_refs(&graph, &full).unwrap();
            let staged_g = rt.stage(&graph, &pairs).unwrap();
            assert_eq!(staged_g.n_dynamic(), 2);
            assert_eq!(staged_g.n_static(), weights.len());
            let staged = rt.run_staged(&staged_g, &[&tok, &len]).unwrap();
            assert!(
                unstaged == staged,
                "{variant} prefill: staged output differs from unstaged"
            );

            // ---- decode b=4: random token/pos/caches
            let graph = format!("tiny3m_{variant}_decode_b4");
            let b = 4usize;
            let kv_shape =
                [b, info.n_heads, info.max_seq, info.head_dim];
            let cache_len: usize = kv_shape.iter().product();
            let token: Vec<i32> = (0..b)
                .map(|_| rng.range(3, info.vocab as i64 - 1) as i32)
                .collect();
            let pos: Vec<i32> =
                (0..b).map(|_| rng.range(1, 12) as i32).collect();
            let tok = runtime::literal_i32(&[b], &token).unwrap();
            let pos_l = runtime::literal_i32(&[b], &pos).unwrap();
            let caches: Vec<runtime::Literal> = (0..2 * info.n_layers)
                .map(|_| {
                    let data: Vec<f32> = (0..cache_len)
                        .map(|_| rng.normal_f32() * 0.1)
                        .collect();
                    runtime::literal_f32(&kv_shape, &data).unwrap()
                })
                .collect();
            let mut full: Vec<&runtime::Literal> = vec![&tok, &pos_l];
            full.extend(caches.iter());
            full.extend(weights.iter());
            let unstaged = rt.run_literal_refs(&graph, &full).unwrap();
            let staged_g = rt.stage(&graph, &pairs).unwrap();
            let mut dynamic: Vec<&runtime::Literal> = vec![&tok, &pos_l];
            dynamic.extend(caches.iter());
            let staged = rt.run_staged(&staged_g, &dynamic).unwrap();
            assert!(
                unstaged == staged,
                "{variant} decode: staged output differs from unstaged"
            );
        }
    });
}

/// Staged GEMM graphs (packed int4 payloads staged once, conversion
/// still fused in-kernel) must also match unstaged execution bit for
/// bit, across fp, W8A8, and the FastGEMM path.
#[test]
fn prop_staged_gemm_graphs_bit_identical_to_unstaged() {
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    Prop::new("staged == unstaged (gemm)").cases(3).check(|rng| {
        let mut rt =
            Runtime::with_backend("artifacts", BackendKind::Native).unwrap();
        let graphs: Vec<_> = rt
            .manifest
            .gemm_graphs("cpu")
            .into_iter()
            .filter(|g| {
                g.m == 1
                    && ["fp", "w8a8", "w4a8_fast"]
                        .contains(&g.variant.as_str())
            })
            .cloned()
            .collect();
        assert!(!graphs.is_empty(), "cpu gemm shape set missing");
        for gi in &graphs {
            let args = random_gemm_args_with(&gi.params, rng).unwrap();
            let n_dyn = gi.dynamic_param_count(&rt.manifest).unwrap();
            let full: Vec<&runtime::Literal> = args.iter().collect();
            let unstaged = rt.run_literal_refs(&gi.name, &full).unwrap();
            let pairs: Vec<(&str, &runtime::Literal)> = gi.params[n_dyn..]
                .iter()
                .map(|p| p.name.as_str())
                .zip(args[n_dyn..].iter())
                .collect();
            let staged_g = rt.stage(&gi.name, &pairs).unwrap();
            let dynamic: Vec<&runtime::Literal> =
                args[..n_dyn].iter().collect();
            let staged = rt.run_staged(&staged_g, &dynamic).unwrap();
            assert!(
                unstaged == staged,
                "{}: staged gemm output differs from unstaged",
                gi.name
            );
        }
    });
}

// ------------------------------------------- native backend interop

/// The engine-path interop contract at the tiny3m weight shapes: for
/// every int4 nibble value, running the packed weights through the
/// native FastGEMM kernel (`unpack_x16` + /16 dequant epilogue) equals
/// the vanilla route (`unpack_int4` to true int4 values, then the plain
/// per-channel epilogue) BIT-EXACTLY.
#[test]
fn prop_fastgemm_epilogue_matches_unpacked_route_bit_exact() {
    use odyssey::runtime::native::{gemm_w4a8_fast, gemm_w8a8};

    // (K, N) pairs used by the tiny3m matrices: attention, gate/up, down
    let shapes = [(256usize, 256usize), (256, 768), (768, 256)];
    Prop::new("fastgemm epilogue interop").cases(3).check(|rng| {
        for &(k, n) in &shapes {
            let m = 2;
            let x = Tensor::randn(&[m, k], rng.next_u64());
            let (xq, s_a) = scale::quant_act_per_token(&x);
            // int4 weights covering ALL 16 nibble values: first rows
            // sweep -8..=7 in every column, the rest are random
            let mut q = Tensor::<i8>::zeros(&[k, n]);
            for i in 0..k {
                for j in 0..n {
                    let v = if i < 16 {
                        i as i32 - 8
                    } else {
                        rng.range(-8, 8) as i32
                    };
                    q.set2(i, j, v as i8);
                }
            }
            let s_w: Vec<f32> =
                (0..n).map(|_| 0.01 + rng.next_f32() * 0.05).collect();
            let p = pack::pack_int4(&q);

            // FastGEMM route: x16 weights, s_w/16 epilogue (inside)
            let fast = gemm_w4a8_fast(&xq, &s_a, &p, &s_w);
            // vanilla route: true int4 values + plain epilogue
            let w4 = pack::unpack_int4(&p);
            assert_eq!(w4, q, "unpack must invert pack");
            let vanilla = gemm_w8a8(&xq, &s_a, &w4, &s_w);

            assert_eq!(
                fast.shape(),
                vanilla.shape(),
                "shape mismatch at ({k},{n})"
            );
            for (i, (a, b)) in fast
                .data()
                .iter()
                .zip(vanilla.data().iter())
                .enumerate()
            {
                assert!(
                    a == b,
                    "({k},{n})[{i}]: fast {a} != vanilla {b} \
                     (must be bit-exact)"
                );
            }
        }
    });
}
