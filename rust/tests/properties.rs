//! Cross-module property tests (mini-propcheck harness; seeds reported
//! on failure).  Pure CPU; the staged-execution parity section
//! synthesizes the tiny3m artifact set on first use.

use odyssey::coordinator::kv::{BlockAllocator, KvState, PagedKv};
use odyssey::coordinator::queue::{Admit, RequestQueue};
use odyssey::coordinator::request::{GenParams, Request};
use odyssey::coordinator::sampler::{
    LogitsTransform, RepetitionPenalty, SampleCtx, SamplerRng,
    SamplerStack, TopP,
};
use odyssey::exp::latency::random_gemm_args_with;
use odyssey::formats::config::ModelInfo;
use odyssey::formats::json::Json;
use odyssey::formats::safetensors::{SafeTensors, StTensor};
use odyssey::model::{self, Checkpoint};
use odyssey::quant::{gptq, lwc, pack, rtn, scale, GptqConfig, QuantRecipe};
use odyssey::runtime::{
    self, synth, BackendKind, KvBlockPool, KvDtype, Runtime,
};
use odyssey::tensor::Tensor;
use odyssey::util::propcheck::Prop;
use odyssey::util::XorShift;

// ---------------------------------------------------------------- quant

/// The FastGEMM identity at the integer level: for any int8 activations
/// and int4 weights, acc(x, 16·w) / 16 == acc(x, w) EXACTLY (s32 math).
#[test]
fn prop_fastgemm_x16_identity() {
    Prop::new("fastgemm x16 identity").cases(200).check(|rng| {
        let k = 2 * (1 + (rng.next_u64() % 32) as usize);
        let n = 1 + (rng.next_u64() % 8) as usize;
        let q: Vec<i8> = (0..k * n).map(|_| rng.range(-8, 8) as i8).collect();
        let x: Vec<i8> =
            (0..k).map(|_| rng.range(-127, 128) as i8).collect();
        let qt = Tensor::from_vec(&[k, n], q);
        let p = pack::pack_int4(&qt);
        let w16 = pack::unpack_x16(&p);
        for j in 0..n {
            let mut acc: i32 = 0;
            let mut acc16: i32 = 0;
            for i in 0..k {
                acc += x[i] as i32 * qt.at2(i, j) as i32;
                acc16 += x[i] as i32 * w16.at2(i, j) as i32;
            }
            assert_eq!(acc16, acc * 16, "x16 accumulate must be exact");
            assert_eq!(acc16 / 16, acc);
        }
    });
}

#[test]
fn prop_lwc_at_least_as_good_as_vanilla() {
    Prop::new("lwc >= vanilla").cases(25).check(|rng| {
        let k = 16 + (rng.next_u64() % 64) as usize;
        let n = 1 + (rng.next_u64() % 6) as usize;
        let w = Tensor::randn(&[k, n], rng.next_u64());
        let r = lwc::lwc(&w, 4);
        for j in 0..n {
            assert!(r.mse[j] <= r.mse_vanilla[j] + 1e-15);
        }
    });
}

#[test]
fn prop_gptq_never_worse_than_rtn_on_calib_objective() {
    Prop::new("gptq <= rtn output-mse").cases(10).check(|rng| {
        let (k, n, t) = (24, 8, 192);
        let w = Tensor::randn(&[k, n], rng.next_u64());
        let mut x = Tensor::randn(&[t, k], rng.next_u64());
        // correlated channels (what GPTQ exploits)
        for i in 0..t {
            let base = x.at2(i, 0);
            for j in 1..4 {
                let v = 0.7 * base + 0.3 * x.at2(i, j);
                x.set2(i, j, v);
            }
        }
        let xt = x.transpose();
        let h = xt.matmul(&x).map(|v| 2.0 * v / t as f32);
        let res =
            gptq::gptq_quantize(&w, &h, &GptqConfig::default(), None)
                .unwrap();
        let w_g = rtn::dequant_per_channel(&res.q, &res.scales);
        let (qr, sr) = rtn::rtn_per_channel(&w, 4, None, None);
        let w_r = rtn::dequant_per_channel(&qr, &sr);
        let e_g = gptq::layer_output_mse(&x, &w, &w_g);
        let e_r = gptq::layer_output_mse(&x, &w, &w_r);
        assert!(
            e_g <= e_r * 1.001,
            "gptq {e_g} must not lose to rtn {e_r}"
        );
    });
}

#[test]
fn prop_act_quant_scales_bound_error() {
    Prop::new("act quant error bound").cases(50).check(|rng| {
        let m = 1 + (rng.next_u64() % 6) as usize;
        let k = 2 + (rng.next_u64() % 48) as usize;
        let x = Tensor::randn(&[m, k], rng.next_u64());
        let (q, s) = scale::quant_act_per_token(&x).unwrap();
        for i in 0..m {
            for j in 0..k {
                let deq = q.at2(i, j) as f32 * s[i];
                assert!((deq - x.at2(i, j)).abs() <= 0.5 * s[i] + 1e-6);
            }
        }
    });
}

// ---------------------------------------------------------- coordinator

#[test]
fn prop_kv_slots_never_double_allocate() {
    Prop::new("kv slot model").cases(50).check(|rng| {
        let b = 2 + (rng.next_u64() % 6) as usize;
        let mut kv = KvState::new(b, 2, 2, 16, 4);
        let mut live: Vec<usize> = Vec::new();
        for step in 0..100u64 {
            if rng.next_f64() < 0.5 && kv.free_slots() > 0 {
                let slot = kv.alloc(step).unwrap();
                assert!(
                    !live.contains(&slot),
                    "slot {slot} double-allocated"
                );
                live.push(slot);
            } else if !live.is_empty() {
                let idx = (rng.next_u64() % live.len() as u64) as usize;
                let slot = live.swap_remove(idx);
                kv.free(slot);
            }
            assert_eq!(kv.free_slots(), b - live.len());
        }
    });
}

#[test]
fn prop_queue_fifo_and_conservation() {
    Prop::new("queue conservation").cases(50).check(|rng| {
        let cap = 4 + (rng.next_u64() % 12) as usize;
        let mut q = RequestQueue::new(cap);
        let mut next_id = 0u64;
        let mut expected: std::collections::VecDeque<u64> =
            Default::default();
        let mut popped: Vec<u64> = Vec::new();
        for _ in 0..200 {
            if rng.next_f64() < 0.6 {
                let r = Request::new(next_id, vec![1; 4],
                                     GenParams::default());
                if q.push(r) == Admit::Accepted {
                    expected.push_back(next_id);
                }
                next_id += 1;
            } else {
                let n = 1 + (rng.next_u64() % 3) as usize;
                let (batch, rej) = q.pop_batch(n, 100);
                assert!(rej.is_empty());
                for r in batch {
                    let want = expected.pop_front().unwrap();
                    assert_eq!(r.id, want, "FIFO violated");
                    popped.push(r.id);
                }
            }
            assert!(q.len() <= cap);
        }
        assert_eq!(q.len(), expected.len());
    });
}

// --------------------------------------------------------------- sampler

/// Top-p keeps exactly the minimal highest-probability prefix whose
/// cumulative mass reaches p: replicate the sort + f64 softmax + CDF
/// walk independently and demand the surviving candidates match index
/// for index, then check the mass bound and its minimality directly.
#[test]
fn prop_top_p_keeps_minimal_mass_prefix() {
    Prop::new("top-p minimal mass prefix").cases(100).check(|rng| {
        let v = 2 + (rng.next_u64() % 64) as usize;
        let logits: Vec<f32> =
            (0..v).map(|_| rng.normal_f32() * 3.0).collect();
        let p = (0.05 + 0.9 * rng.next_f64()) as f32;
        let mut cands: Vec<(usize, f32)> =
            logits.iter().copied().enumerate().collect();
        TopP(p).apply(&SampleCtx { prompt: &[], generated: &[] },
                      &mut cands);
        assert!(!cands.is_empty(), "top-p must keep a candidate");

        // independent reference: sort desc (ties by vocab index), f64
        // max-subtracted softmax, smallest prefix reaching p
        let mut sorted: Vec<(usize, f32)> =
            logits.iter().copied().enumerate().collect();
        sorted.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        let maxv = sorted.iter().map(|c| c.1).fold(f32::MIN, f32::max);
        let exps: Vec<f64> = sorted
            .iter()
            .map(|c| ((c.1 - maxv) as f64).exp())
            .collect();
        let z: f64 = exps.iter().sum();
        let mut cum = 0.0f64;
        let mut keep = sorted.len();
        for (k, e) in exps.iter().enumerate() {
            cum += e / z;
            if cum >= p as f64 {
                keep = k + 1;
                break;
            }
        }
        assert_eq!(cands, sorted[..keep].to_vec(), "p={p}");

        // mass bound: kept mass reaches p, and dropping the last kept
        // candidate would fall below it (minimality)
        let mass: f64 = exps[..keep].iter().sum::<f64>() / z;
        assert!(mass + 1e-9 >= p as f64, "mass {mass} < p {p}");
        if keep > 1 {
            let without_last: f64 =
                exps[..keep - 1].iter().sum::<f64>() / z;
            assert!(
                without_last < p as f64,
                "kept prefix is not minimal (p={p})"
            );
        }
    });
}

/// The repetition penalty demotes tokens seen in the prompt or the
/// generation and leaves every other logit BITWISE untouched — and it
/// never drops a candidate.
#[test]
fn prop_repetition_penalty_only_demotes_seen() {
    Prop::new("repetition penalty demotes only seen").cases(100).check(
        |rng| {
            let v = 8 + (rng.next_u64() % 56) as usize;
            let logits: Vec<f32> =
                (0..v).map(|_| rng.normal_f32() * 2.0).collect();
            let prompt: Vec<i32> = (0..4)
                .map(|_| (rng.next_u64() % v as u64) as i32)
                .collect();
            let generated: Vec<i32> = (0..3)
                .map(|_| (rng.next_u64() % v as u64) as i32)
                .collect();
            let penalty = (1.05 + rng.next_f64()) as f32;
            let ctx =
                SampleCtx { prompt: &prompt, generated: &generated };
            let mut cands: Vec<(usize, f32)> =
                logits.iter().copied().enumerate().collect();
            RepetitionPenalty(penalty).apply(&ctx, &mut cands);
            assert_eq!(cands.len(), v, "penalty drops no candidates");
            for (i, l) in &cands {
                let seen = prompt
                    .iter()
                    .chain(generated.iter())
                    .any(|&t| t as usize == *i);
                let orig = logits[*i];
                if seen {
                    let want = if orig > 0.0 {
                        orig / penalty
                    } else {
                        orig * penalty
                    };
                    assert_eq!(*l, want, "seen token {i}");
                    assert!(*l <= orig, "penalty must demote, not boost");
                } else {
                    assert_eq!(
                        l.to_bits(),
                        orig.to_bits(),
                        "unseen token {i} must be bitwise untouched"
                    );
                }
            }
        },
    );
}

/// Whatever subset of transforms a request enables, the stack applies
/// them in the FIXED canonical order: repetition penalty → temperature
/// → top-k → top-p (neutral settings omitted).
#[test]
fn prop_sampler_stack_order_is_fixed() {
    Prop::new("sampler stack order").cases(100).check(|rng| {
        let penalty_on = rng.next_f64() < 0.5;
        let top_k_on = rng.next_f64() < 0.5;
        let top_p_on = rng.next_f64() < 0.5;
        let p = GenParams {
            temperature: 0.7,
            repetition_penalty: if penalty_on { 1.2 } else { 1.0 },
            top_k: if top_k_on { 5 } else { 0 },
            top_p: if top_p_on { 0.9 } else { 1.0 },
            ..Default::default()
        };
        let names = SamplerStack::from_params(&p).names();
        let mut expect = Vec::new();
        if penalty_on {
            expect.push("repetition_penalty");
        }
        expect.push("temperature");
        if top_k_on {
            expect.push("top_k");
        }
        if top_p_on {
            expect.push("top_p");
        }
        assert_eq!(names, expect);
    });
}

/// The greedy bypass is the EXACT historical argmax (first max wins on
/// ties) and consumes no rng draw — the seeded-stream back-compat
/// contract for every pre-sampler request.
#[test]
fn prop_greedy_stack_is_exact_historical_argmax() {
    Prop::new("greedy == historical argmax").cases(200).check(|rng| {
        let v = 2 + (rng.next_u64() % 128) as usize;
        let mut logits: Vec<f32> =
            (0..v).map(|_| rng.normal_f32()).collect();
        // inject ties sometimes: first-max-wins must be preserved
        if rng.next_f64() < 0.3 {
            let a = (rng.next_u64() % v as u64) as usize;
            let b = (rng.next_u64() % v as u64) as usize;
            logits[b] = logits[a];
        }
        // the pre-refactor inline loop, verbatim
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        let stack = SamplerStack::from_params(&GenParams {
            temperature: 0.0,
            ..Default::default()
        });
        let mut srng = SamplerRng::new(rng.next_u64());
        let got = stack
            .sample(
                &logits,
                &SampleCtx { prompt: &[], generated: &[] },
                &mut srng,
            )
            .unwrap();
        assert_eq!(got, best as i32);
        assert_eq!(srng.draws(), 0, "greedy must consume no draw");
    });
}

// ------------------------------------------------------ paged KV blocks

/// Random alloc / alloc_n / free interleavings: no double allocation,
/// double frees rejected, `free + held == pool size` at every step, and
/// freed blocks recycle.
#[test]
fn prop_block_allocator_conserves_and_recycles() {
    Prop::new("block allocator conservation").cases(50).check(|rng| {
        let n = 4 + (rng.next_u64() % 29) as usize;
        let mut a = BlockAllocator::new(n);
        let mut held: Vec<u32> = Vec::new();
        for _ in 0..300 {
            match rng.next_u64() % 4 {
                0 | 1 => match a.alloc() {
                    Some(b) => {
                        assert!(
                            !held.contains(&b),
                            "block {b} double-allocated"
                        );
                        held.push(b);
                    }
                    None => assert_eq!(
                        held.len(),
                        n,
                        "alloc refused with free blocks"
                    ),
                },
                2 => {
                    let want = 1 + (rng.next_u64() % 4) as usize;
                    match a.alloc_n(want) {
                        Some(bs) => {
                            assert_eq!(bs.len(), want);
                            for b in bs {
                                assert!(!held.contains(&b));
                                held.push(b);
                            }
                        }
                        None => assert!(
                            n - held.len() < want,
                            "all-or-nothing refused with capacity"
                        ),
                    }
                }
                _ => {
                    if !held.is_empty() {
                        let i = (rng.next_u64() % held.len() as u64)
                            as usize;
                        let b = held.swap_remove(i);
                        a.free(b).unwrap();
                        assert!(
                            a.free(b).is_err(),
                            "double free of {b} must error"
                        );
                    }
                }
            }
            assert_eq!(
                a.free_blocks() + held.len(),
                n,
                "conservation violated"
            );
        }
        for b in held.drain(..) {
            a.free(b).unwrap();
        }
        let all = a.alloc_n(n).expect("freed blocks must recycle");
        assert_eq!(all.len(), n);
    });
}

/// Random admit / extend+advance / release interleavings on the paged
/// manager (unique prompts — no sharing here; the prefix-cache fuzz
/// below covers that): every block is on the free list or in exactly
/// one table, extension only refuses when the pool is truly dry, and a
/// drained manager returns every block.
#[test]
fn prop_paged_kv_lifecycle_never_leaks_blocks() {
    Prop::new("paged kv lifecycle").cases(30).check(|rng| {
        let blocks = 6 + (rng.next_u64() % 20) as usize;
        let mut kv = PagedKv::new(4, 2, 2, 64, 4, 4, blocks);
        let mut live: Vec<(usize, u64)> = Vec::new();
        for step in 0..200u64 {
            match rng.next_u64() % 3 {
                0 => {
                    let plen = 1 + (rng.next_u64() % 16) as usize;
                    // unique tokens per admission -> index never hits
                    let prompt: Vec<i32> = (0..plen as i32)
                        .map(|i| 1000 * (step as i32 + 1) + i)
                        .collect();
                    match kv.alloc_seq(step, &prompt) {
                        Some(a) => {
                            assert_eq!(a.start, 0, "unique prompts miss");
                            let slot = a.slot;
                            assert!(
                                live.iter().all(|&(s, _)| s != slot),
                                "slot {slot} double-assigned"
                            );
                            kv.finish_prefill(slot, plen).unwrap();
                            live.push((slot, step));
                        }
                        None => assert!(
                            kv.free_slots() == 0
                                || kv.available_blocks()
                                    < kv.blocks_for(plen),
                            "admission refused with capacity"
                        ),
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let i = (rng.next_u64() % live.len() as u64)
                            as usize;
                        let (slot, _) = live[i];
                        if kv.pos(slot) + 2 < 64 {
                            if kv.ensure_write_capacity(slot) {
                                kv.advance(slot).unwrap();
                            } else {
                                assert_eq!(
                                    kv.free_blocks(),
                                    0,
                                    "extend refused with free blocks"
                                );
                            }
                        }
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = (rng.next_u64() % live.len() as u64)
                            as usize;
                        let (slot, _) = live.swap_remove(i);
                        kv.free_seq(slot);
                    }
                }
            }
            kv.check_conservation().unwrap();
            assert_eq!(kv.free_blocks() + kv.blocks_in_use(), blocks);
        }
        for (slot, _) in live.drain(..) {
            kv.free_seq(slot);
        }
        assert_eq!(kv.free_blocks(), blocks, "blocks leaked");
        kv.check_conservation().unwrap();
    });
}

/// The PR 4 tentpole fuzz: random interleavings of
/// admit-with-shared-prefix / decode-with-CoW-fork / fork_seq / free /
/// index-evict — now also speculative verify windows
/// (`ensure_window_capacity` + `truncate_seq` rollback) and multi-turn
/// donation of GENERATED blocks at free — on the refcounted prefix
/// cache.  After EVERY op, `check_conservation` proves `free +
/// Σ refcounted-unique == pool size` with each block's refcount equal
/// to its reachable holds (tables + index) — no leak, no double free —
/// and every write target is PRIVATE (refcount 1) after the write path
/// runs, so no block is reachable from two tables once a fork writes.
#[test]
fn prop_prefix_cache_refcount_conservation() {
    Prop::new("prefix cache refcount conservation").cases(25).check(
        |rng| {
            let blocks = 10 + (rng.next_u64() % 22) as usize;
            let bs = 4usize;
            let max_seq = 64usize;
            let cap = 4 + (rng.next_u64() % 16) as usize;
            let mut kv = PagedKv::new(4, 2, 2, max_seq, 4, bs, blocks)
                .with_prefix_cap(cap);
            // prompt family: 3 stems; admissions take a stem prefix
            // (shared) plus an optional private tail token
            let stems: Vec<Vec<i32>> = (0..3i32)
                .map(|s| (0..24).map(|i| 100 * (s + 1) + i).collect())
                .collect();
            // (slot, request id, every token whose K/V the cache
            // holds: prompt ++ generated — donated in full at free)
            let mut live: Vec<(usize, u64, Vec<i32>)> = Vec::new();
            let mut gen_ctr = 0i32;
            for step in 0..300u64 {
                match rng.next_u64() % 10 {
                    // admit with a (likely shared) prefix, then do what
                    // the engine does: prefill + donate
                    0 | 1 | 2 => {
                        let stem =
                            &stems[(rng.next_u64() % 3) as usize];
                        let take =
                            4 + (rng.next_u64() % 21) as usize;
                        let mut prompt: Vec<i32> =
                            stem[..take.min(stem.len())].to_vec();
                        if rng.next_f64() < 0.3 {
                            prompt.push(-(step as i32) - 1);
                        }
                        let plen = prompt.len();
                        match kv.alloc_seq(step, &prompt) {
                            Some(a) => {
                                assert!(
                                    a.start < plen,
                                    "one position is always recomputed"
                                );
                                assert!(live
                                    .iter()
                                    .all(|l| l.0 != a.slot));
                                // prefill writes start..plen through
                                // the table: every touched block must
                                // be private after admission
                                for idx in (a.start / bs)
                                    ..kv.blocks_for(plen)
                                {
                                    let b = kv.table(a.slot)[idx];
                                    assert_eq!(
                                        kv.ref_count(b),
                                        1,
                                        "prefill write range must be \
                                         private (block {b})"
                                    );
                                }
                                kv.finish_prefill(a.slot, plen)
                                    .unwrap();
                                kv.donate_prefix(a.slot, &prompt);
                                live.push((a.slot, step, prompt));
                            }
                            None => assert!(
                                !kv.admission_feasible(&prompt, 0),
                                "admission refused although feasible \
                                 (feasible <=> success is exact)"
                            ),
                        }
                    }
                    // decode write: growth + CoW forks of shared tails
                    3 | 4 => {
                        if !live.is_empty() {
                            let i = (rng.next_u64()
                                % live.len() as u64)
                                as usize;
                            let slot = live[i].0;
                            if kv.pos(slot) + 2 < max_seq {
                                if kv.ensure_write_capacity(slot) {
                                    let b = kv.table(slot)
                                        [kv.pos(slot) / bs];
                                    assert_eq!(
                                        kv.ref_count(b),
                                        1,
                                        "write target must be private \
                                         after the CoW path"
                                    );
                                    kv.advance(slot).unwrap();
                                    gen_ctr += 1;
                                    live[i]
                                        .2
                                        .push(-1_000_000 - gen_ctr);
                                } else {
                                    assert_eq!(
                                        kv.available_blocks(),
                                        0,
                                        "write refused with \
                                         reclaimable capacity"
                                    );
                                }
                            }
                        }
                    }
                    // fork a live sequence (parallel-sampling shape):
                    // twins share every block until a write splits them
                    5 => {
                        if !live.is_empty() {
                            let i = (rng.next_u64()
                                % live.len() as u64)
                                as usize;
                            let slot = live[i].0;
                            if let Some(twin) =
                                kv.fork_seq(slot, 100_000 + step)
                            {
                                assert_eq!(
                                    kv.table(twin),
                                    kv.table(slot),
                                    "twins share every block"
                                );
                                let hist = live[i].2.clone();
                                live.push((twin, 100_000 + step, hist));
                            }
                        }
                    }
                    // free (completion / preemption): donate the whole
                    // cached thread — prompt AND generated blocks — so
                    // a follow-up turn can resume it, then release only
                    // this sequence's holds
                    6 => {
                        if !live.is_empty() {
                            let i = (rng.next_u64()
                                % live.len() as u64)
                                as usize;
                            let (slot, _, hist) = live.swap_remove(i);
                            assert_eq!(
                                hist.len(),
                                kv.pos(slot),
                                "tracked tokens drifted from pos"
                            );
                            kv.donate_prefix(slot, &hist);
                            kv.free_seq(slot);
                        }
                    }
                    // explicit index eviction
                    7 => {
                        let _ = kv.reclaim_index_lru();
                    }
                    // speculative verify window: back [pos, upto) with
                    // private pages, then commit a random accepted
                    // prefix and roll the rejected rows' blocks back
                    _ => {
                        if !live.is_empty() {
                            let i = (rng.next_u64()
                                % live.len() as u64)
                                as usize;
                            let slot = live[i].0;
                            let pos = kv.pos(slot);
                            let upto = (pos
                                + 2
                                + (rng.next_u64() % 4) as usize)
                                .min(max_seq);
                            let before = kv.table(slot).len();
                            if upto <= pos {
                                // already parked at max_seq: no window
                            } else if kv
                                .ensure_window_capacity(slot, upto)
                            {
                                for idx in
                                    (pos / bs)..kv.blocks_for(upto)
                                {
                                    let b = kv.table(slot)[idx];
                                    assert_eq!(
                                        kv.ref_count(b),
                                        1,
                                        "window write range must be \
                                         private (block {b})"
                                    );
                                }
                                let commit = pos
                                    + 1
                                    + (rng.next_u64()
                                        % (upto - pos) as u64)
                                        as usize;
                                kv.truncate_seq(slot, commit);
                                assert_eq!(kv.pos(slot), commit);
                                for _ in pos..commit {
                                    gen_ctr += 1;
                                    live[i]
                                        .2
                                        .push(-1_000_000 - gen_ctr);
                                }
                            } else {
                                assert_eq!(
                                    kv.available_blocks(),
                                    0,
                                    "window refused with reclaimable \
                                     capacity"
                                );
                                assert_eq!(
                                    kv.table(slot).len(),
                                    before,
                                    "failed window grow must restore \
                                     the table"
                                );
                            }
                        }
                    }
                }
                kv.check_conservation().unwrap_or_else(|e| {
                    panic!("conservation broke at step {step}: {e}")
                });
                assert!(
                    kv.prefix_index_blocks() <= cap,
                    "index cap violated"
                );
            }
            // drain: free everything and flush the index — the pool
            // must come back whole
            for (slot, _, _) in live.drain(..) {
                kv.free_seq(slot);
            }
            kv.flush_prefix_index();
            assert_eq!(kv.free_blocks(), blocks, "blocks leaked");
            kv.check_conservation().unwrap();
        },
    );
}

/// Partial prefill (prefix-cache suffix computation) must be
/// BIT-IDENTICAL to the full staged prefill: run a full paged prefill
/// of a prompt, donate nothing — instead re-run the SAME prompt as a
/// partial prefill over a second table whose prefix blocks are the
/// first run's, for every variant.  Logits at every computed position
/// and the K/V written through the tables must match exactly.
#[test]
fn prop_partial_prefill_bit_identical_to_full() {
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    Prop::new("partial == full (prefill)").cases(2).check(|rng| {
        let mut rt =
            Runtime::with_backend("artifacts", BackendKind::Native)
                .unwrap();
        let info = rt.manifest.model("tiny3m").unwrap().clone();
        let group = rt.manifest.group_size;
        let (nl, nh, dh) = (info.n_layers, info.n_heads, info.head_dim);
        let smax = info.max_seq;
        for variant in ["fp", "w8a8", "w4a8_fast"] {
            let ckpt = random_checkpoint(&info, rng);
            let qw = model::quantize_checkpoint(
                &ckpt,
                None,
                &QuantRecipe::vanilla_w4(),
                variant,
                group,
            )
            .unwrap();
            let weights: Vec<runtime::Literal> = qw
                .tensors
                .iter()
                .map(|t| runtime::literal_from_st(t).unwrap())
                .collect();
            let pairs: Vec<(&str, &runtime::Literal)> = qw
                .names
                .iter()
                .map(String::as_str)
                .zip(weights.iter())
                .collect();
            let graph = format!("tiny3m_{variant}_prefill_b1");
            let gi = rt.manifest.graph(&graph).unwrap().clone();
            let (b, s) = (gi.batch, gi.seq);
            assert_eq!(b, 1);
            let staged = rt.stage(&graph, &pairs).unwrap();

            // random prompt spanning >= 2 blocks; random block-aligned
            // split point for the partial run (capped to plen-1, so an
            // aligned full hit exercises the recompute-last-position
            // shape the engine's CoW tail fork produces)
            let bs_kv = 4usize;
            let plen = 9 + (rng.next_u64() % 10) as usize; // 9..=18
            let n_full = plen / bs_kv;
            let start = bs_kv
                * (1 + (rng.next_u64() % n_full.max(1) as u64)
                    as usize)
                .min(n_full);
            // keep at least one computed position
            let start = start.min(plen - 1);
            let mut tokens = vec![0i32; b * s];
            for t in tokens.iter_mut().take(plen) {
                *t = rng.range(3, info.vocab as i64 - 1) as i32;
            }
            let lengths = [plen as i32];

            // FULL paged prefill into pool A (reference)
            let n_blocks = 16usize;
            let need = plen.div_ceil(bs_kv);
            let table_a: Vec<u32> = (0..need as u32).collect();
            let mut pool_a =
                KvBlockPool::new(n_blocks, bs_kv, nl, nh, dh);
            let full_logits = rt
                .run_prefill_paged(
                    &staged,
                    &tokens,
                    &lengths,
                    &[0],
                    &[plen as i32],
                    &mut pool_a,
                    &[&table_a],
                )
                .unwrap()
                .to_vec::<f32>()
                .unwrap();

            // PARTIAL prefill into pool B: history blocks share pool
            // A's content (scattered over shuffled ids), suffix
            // computed fresh
            let mut ids: Vec<u32> = (0..n_blocks as u32).collect();
            for i in (1..ids.len()).rev() {
                let j = (rng.next_u64() % (i as u64 + 1)) as usize;
                ids.swap(i, j);
            }
            let table_b: Vec<u32> = ids[..need].to_vec();
            let mut pool_b =
                KvBlockPool::new(n_blocks, bs_kv, nl, nh, dh);
            for l in 0..nl {
                let (kr, vr) = pool_a
                    .gather_row(l, &table_a, start, smax)
                    .unwrap();
                pool_b
                    .scatter_row(l, &table_b, start, smax, &kr, &vr)
                    .unwrap();
            }
            let partial_logits = rt
                .run_prefill_paged(
                    &staged,
                    &tokens,
                    &lengths,
                    &[start as i32],
                    &[plen as i32],
                    &mut pool_b,
                    &[&table_b],
                )
                .unwrap()
                .to_vec::<f32>()
                .unwrap();

            // logits at every COMPUTED position must match bit for bit
            let v = info.vocab;
            for p in start..plen {
                assert!(
                    full_logits[p * v..(p + 1) * v]
                        == partial_logits[p * v..(p + 1) * v],
                    "{variant} pos {p}: partial-prefill logits differ \
                     (start={start}, plen={plen})"
                );
            }
            // the K/V written through both tables must agree at every
            // prompt position
            for l in 0..nl {
                let (ka, va) = pool_a
                    .gather_row(l, &table_a, plen, smax)
                    .unwrap();
                let (kb, vb) = pool_b
                    .gather_row(l, &table_b, plen, smax)
                    .unwrap();
                assert!(
                    ka == kb && va == vb,
                    "{variant} layer {l}: partial-prefill K/V differs \
                     (start={start}, plen={plen})"
                );
            }
        }
    });
}

/// Chunked prefill must be BIT-IDENTICAL to the one-shot prefill under
/// ANY chunk schedule: run a full paged prefill of a random prompt,
/// then replay the SAME prompt through a random sequence of
/// `[start, end)` windows (random per-chunk budgets through the real
/// `sched::chunk_end` sizing rule, optionally starting from a cached
/// prefix as the engine does on an index hit) into a second pool over
/// shuffled block ids.  Logits at every computed position and the K/V
/// written through the tables must match exactly, for every serving
/// variant — the contract that lets `ODYSSEY_NO_CHUNKING=1` and the
/// fused scheduler produce identical token streams.
#[test]
fn prop_chunked_prefill_bit_identical_to_unchunked() {
    use odyssey::coordinator::sched::chunk_end;
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    Prop::new("chunked == unchunked (prefill)").cases(2).check(|rng| {
        let mut rt =
            Runtime::with_backend("artifacts", BackendKind::Native)
                .unwrap();
        let info = rt.manifest.model("tiny3m").unwrap().clone();
        let group = rt.manifest.group_size;
        let (nl, nh, dh) = (info.n_layers, info.n_heads, info.head_dim);
        let smax = info.max_seq;
        for variant in ["fp", "w8a8", "w4a8_fast"] {
            let ckpt = random_checkpoint(&info, rng);
            let qw = model::quantize_checkpoint(
                &ckpt,
                None,
                &QuantRecipe::vanilla_w4(),
                variant,
                group,
            )
            .unwrap();
            let weights: Vec<runtime::Literal> = qw
                .tensors
                .iter()
                .map(|t| runtime::literal_from_st(t).unwrap())
                .collect();
            let pairs: Vec<(&str, &runtime::Literal)> = qw
                .names
                .iter()
                .map(String::as_str)
                .zip(weights.iter())
                .collect();
            let graph = format!("tiny3m_{variant}_prefill_b1");
            let gi = rt.manifest.graph(&graph).unwrap().clone();
            let (b, s) = (gi.batch, gi.seq);
            assert_eq!(b, 1);
            let staged = rt.stage(&graph, &pairs).unwrap();

            let bs_kv = 4usize;
            let plen = 9 + (rng.next_u64() % 10) as usize; // 9..=18
            let mut tokens = vec![0i32; b * s];
            for t in tokens.iter_mut().take(plen) {
                *t = rng.range(3, info.vocab as i64 - 1) as i32;
            }
            let lengths = [plen as i32];
            let n_blocks = 16usize;
            let need = plen.div_ceil(bs_kv);

            // reference: ONE window [0, plen) into pool A
            let table_a: Vec<u32> = (0..need as u32).collect();
            let mut pool_a =
                KvBlockPool::new(n_blocks, bs_kv, nl, nh, dh);
            let full_logits = rt
                .run_prefill_paged(
                    &staged,
                    &tokens,
                    &lengths,
                    &[0],
                    &[plen as i32],
                    &mut pool_a,
                    &[&table_a],
                )
                .unwrap()
                .to_vec::<f32>()
                .unwrap();

            // chunked replay into pool B over shuffled block ids,
            // optionally starting from a cached prefix (the engine's
            // prefix-hit shape: chunking starts at the first uncached
            // token)
            let mut ids: Vec<u32> = (0..n_blocks as u32).collect();
            for i in (1..ids.len()).rev() {
                let j = (rng.next_u64() % (i as u64 + 1)) as usize;
                ids.swap(i, j);
            }
            let table_b: Vec<u32> = ids[..need].to_vec();
            let mut pool_b =
                KvBlockPool::new(n_blocks, bs_kv, nl, nh, dh);
            let start0 = if rng.next_f64() < 0.5 {
                // block-aligned cached prefix, at least one position
                // left to compute
                (bs_kv
                    * (1 + (rng.next_u64() % need.max(1) as u64)
                        as usize))
                    .min(plen - 1)
            } else {
                0
            };
            for l in 0..nl {
                let (kr, vr) = pool_a
                    .gather_row(l, &table_a, start0, smax)
                    .unwrap();
                pool_b
                    .scatter_row(l, &table_b, start0, smax, &kr, &vr)
                    .unwrap();
            }

            let v = info.vocab;
            let mut chunk_logits = vec![0f32; b * s * v];
            let mut done = start0;
            let mut n_chunks = 0usize;
            while done < plen {
                let budget = 1 + (rng.next_u64() % 6) as usize; // 1..=6
                let end = chunk_end(done, plen, budget, bs_kv, true);
                assert!(end > done, "chunk must make progress");
                let out = rt
                    .run_prefill_paged(
                        &staged,
                        &tokens,
                        &lengths,
                        &[done as i32],
                        &[end as i32],
                        &mut pool_b,
                        &[&table_b],
                    )
                    .unwrap()
                    .to_vec::<f32>()
                    .unwrap();
                for p in done..end {
                    chunk_logits[p * v..(p + 1) * v]
                        .copy_from_slice(&out[p * v..(p + 1) * v]);
                }
                done = end;
                n_chunks += 1;
            }
            assert!(
                start0 > 0 || n_chunks >= 2 || plen <= 6,
                "schedule degenerated to one chunk (plen={plen})"
            );

            // logits at every computed position must match bit for bit
            for p in start0..plen {
                assert!(
                    full_logits[p * v..(p + 1) * v]
                        == chunk_logits[p * v..(p + 1) * v],
                    "{variant} pos {p}: chunked logits differ \
                     (start0={start0}, plen={plen}, chunks={n_chunks})"
                );
            }
            // the K/V written through both tables must agree at every
            // prompt position
            for l in 0..nl {
                let (ka, va) = pool_a
                    .gather_row(l, &table_a, plen, smax)
                    .unwrap();
                let (kb, vb) = pool_b
                    .gather_row(l, &table_b, plen, smax)
                    .unwrap();
                assert!(
                    ka == kb && va == vb,
                    "{variant} layer {l}: chunked K/V differs \
                     (start0={start0}, plen={plen})"
                );
            }
        }
    });
}

// --------------------------------------------------------------- formats

fn random_json(rng: &mut XorShift, depth: usize) -> Json {
    match if depth == 0 { rng.next_u64() % 4 } else { rng.next_u64() % 6 } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
        3 => {
            let n = rng.next_u64() % 8;
            let n_special = (rng.next_u64() % 4) as usize;
            let mut s: String = (0..n)
                .map(|i| char::from(b'a' + ((rng.next_u64() + i) % 26) as u8))
                .collect();
            s.extend(['\\', '"', '\n'].into_iter().take(n_special));
            Json::Str(s)
        }
        4 => Json::Arr(
            (0..rng.next_u64() % 4)
                .map(|_| random_json(rng, depth - 1))
                .collect(),
        ),
        _ => Json::Obj(
            (0..rng.next_u64() % 4)
                .map(|i| {
                    (format!("k{i}"), random_json(rng, depth - 1))
                })
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    Prop::new("json emit/parse roundtrip").cases(200).check(|rng| {
        let v = random_json(rng, 3);
        let text = v.emit();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("parse failed on {text}: {e}"));
        assert_eq!(back, v, "roundtrip mismatch for {text}");
    });
}

#[test]
fn prop_safetensors_roundtrip() {
    Prop::new("safetensors roundtrip").cases(50).check(|rng| {
        let mut st = SafeTensors::new();
        let n_tensors = 1 + rng.next_u64() % 5;
        for i in 0..n_tensors {
            let rows = 1 + (rng.next_u64() % 8) as usize;
            let cols = 1 + (rng.next_u64() % 8) as usize;
            match rng.next_u64() % 3 {
                0 => st.insert(
                    &format!("t{i}"),
                    StTensor::from_f32(&Tensor::randn(
                        &[rows, cols],
                        rng.next_u64(),
                    )),
                ),
                1 => st.insert(
                    &format!("t{i}"),
                    StTensor::from_i8(&Tensor::from_vec(
                        &[rows * cols],
                        (0..rows * cols)
                            .map(|_| rng.range(-128, 128) as i8)
                            .collect(),
                    )),
                ),
                _ => st.insert(
                    &format!("t{i}"),
                    StTensor::from_i32(&Tensor::from_vec(
                        &[rows, cols],
                        (0..rows * cols)
                            .map(|_| rng.range(-1000, 1000) as i32)
                            .collect(),
                    )),
                ),
            }
        }
        let bytes = st.to_bytes();
        let back = SafeTensors::from_bytes(&bytes).unwrap();
        assert_eq!(back.len(), st.len());
        for name in st.names() {
            let a = st.get(name).unwrap();
            let b = back.get(name).unwrap();
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.bytes, b.bytes);
        }
    });
}

// ------------------------------------------------------------- corrupted

#[test]
fn corrupted_safetensors_rejected_not_panicking() {
    Prop::new("safetensors fuzz").cases(100).check(|rng| {
        let mut st = SafeTensors::new();
        st.insert(
            "x",
            StTensor::from_f32(&Tensor::randn(&[4, 4], 1)),
        );
        let mut bytes = st.to_bytes();
        // flip random bytes: must either parse or error, never panic
        for _ in 0..3 {
            let i = (rng.next_u64() % bytes.len() as u64) as usize;
            bytes[i] ^= (rng.next_u64() & 0xFF) as u8;
        }
        let _ = SafeTensors::from_bytes(&bytes);
    });
}

#[test]
fn corrupted_json_rejected_not_panicking() {
    Prop::new("json fuzz").cases(200).check(|rng| {
        let src = r#"{"a": [1, 2, {"b": "str"}], "c": -2.5e3}"#;
        let mut bytes = src.as_bytes().to_vec();
        for _ in 0..2 {
            let i = (rng.next_u64() % bytes.len() as u64) as usize;
            bytes[i] = (rng.next_u64() % 128) as u8;
        }
        if let Ok(text) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(text); // must not panic
        }
    });
}

// ------------------------------------- staged execution parity (tentpole)

/// Random tiny3m-shaped checkpoint (weights drawn fresh per case, so
/// the parity property ranges over graphs, not one fixed weight set).
fn random_checkpoint(info: &ModelInfo, rng: &mut XorShift) -> Checkpoint {
    let (d, f, v) = (info.d_model, info.d_ff, info.vocab);
    let mut tensors = std::collections::BTreeMap::new();
    for name in model::weight_names(info) {
        let leaf = name.rsplit('.').next().unwrap();
        let t = match leaf {
            "attn_norm" | "mlp_norm" | "norm_f" => {
                Tensor::randn(&[d], rng.next_u64()).map(|x| 1.0 + 0.05 * x)
            }
            "wq" | "wk" | "wv" | "wo" => Tensor::randn(&[d, d], rng.next_u64())
                .map(|x| x / (d as f32).sqrt()),
            "w_gate" | "w_up" => Tensor::randn(&[d, f], rng.next_u64())
                .map(|x| x / (d as f32).sqrt()),
            "w_down" => Tensor::randn(&[f, d], rng.next_u64())
                .map(|x| x / (f as f32).sqrt()),
            "embed" => {
                Tensor::randn(&[v, d], rng.next_u64()).map(|x| 0.02 * x)
            }
            "lm_head" => Tensor::randn(&[d, v], rng.next_u64())
                .map(|x| x / (d as f32).sqrt()),
            other => panic!("unexpected weight leaf {other}"),
        };
        tensors.insert(name, t);
    }
    Checkpoint { info: info.clone(), tensors }
}

/// `execute_staged` must be BIT-IDENTICAL to `execute` on the serving
/// graphs for the fp-sim, W8A8, and W4A8-fast paths — staging moves the
/// weight parse (including the SINT4toS8 x16 unpack) out of the step,
/// it must not change a single output bit.
#[test]
fn prop_staged_serving_graphs_bit_identical_to_unstaged() {
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    Prop::new("staged == unstaged (serving)").cases(2).check(|rng| {
        let mut rt =
            Runtime::with_backend("artifacts", BackendKind::Native).unwrap();
        let info = rt.manifest.model("tiny3m").unwrap().clone();
        let group = rt.manifest.group_size;
        for variant in ["fp", "w8a8", "w4a8_fast"] {
            let ckpt = random_checkpoint(&info, rng);
            let qw = model::quantize_checkpoint(
                &ckpt,
                None,
                &QuantRecipe::vanilla_w4(),
                variant,
                group,
            )
            .unwrap();
            let weights: Vec<runtime::Literal> = qw
                .tensors
                .iter()
                .map(|t| runtime::literal_from_st(t).unwrap())
                .collect();
            let pairs: Vec<(&str, &runtime::Literal)> = qw
                .names
                .iter()
                .map(String::as_str)
                .zip(weights.iter())
                .collect();

            // ---- prefill b=1: random prompt
            let graph = format!("tiny3m_{variant}_prefill_b1");
            let gi = rt.manifest.graph(&graph).unwrap().clone();
            let (b, s) = (gi.batch, gi.seq);
            let plen = 4 + (rng.next_u64() % 8) as usize;
            let mut tokens = vec![0i32; b * s];
            for t in tokens.iter_mut().take(plen) {
                *t = rng.range(3, info.vocab as i64 - 1) as i32;
            }
            let tok = runtime::literal_i32(&[b, s], &tokens).unwrap();
            let len =
                runtime::literal_i32(&[b], &[plen as i32]).unwrap();
            let mut full: Vec<&runtime::Literal> = vec![&tok, &len];
            full.extend(weights.iter());
            let unstaged = rt.run_literal_refs(&graph, &full).unwrap();
            let staged_g = rt.stage(&graph, &pairs).unwrap();
            assert_eq!(staged_g.n_dynamic(), 2);
            assert_eq!(staged_g.n_static(), weights.len());
            let staged = rt.run_staged(&staged_g, &[&tok, &len]).unwrap();
            assert!(
                unstaged == staged,
                "{variant} prefill: staged output differs from unstaged"
            );

            // ---- decode b=4: random token/pos/caches
            let graph = format!("tiny3m_{variant}_decode_b4");
            let b = 4usize;
            let kv_shape =
                [b, info.n_heads, info.max_seq, info.head_dim];
            let cache_len: usize = kv_shape.iter().product();
            let token: Vec<i32> = (0..b)
                .map(|_| rng.range(3, info.vocab as i64 - 1) as i32)
                .collect();
            let pos: Vec<i32> =
                (0..b).map(|_| rng.range(1, 12) as i32).collect();
            let tok = runtime::literal_i32(&[b], &token).unwrap();
            let pos_l = runtime::literal_i32(&[b], &pos).unwrap();
            let caches: Vec<runtime::Literal> = (0..2 * info.n_layers)
                .map(|_| {
                    let data: Vec<f32> = (0..cache_len)
                        .map(|_| rng.normal_f32() * 0.1)
                        .collect();
                    runtime::literal_f32(&kv_shape, &data).unwrap()
                })
                .collect();
            let mut full: Vec<&runtime::Literal> = vec![&tok, &pos_l];
            full.extend(caches.iter());
            full.extend(weights.iter());
            let unstaged = rt.run_literal_refs(&graph, &full).unwrap();
            let staged_g = rt.stage(&graph, &pairs).unwrap();
            let mut dynamic: Vec<&runtime::Literal> = vec![&tok, &pos_l];
            dynamic.extend(caches.iter());
            let staged = rt.run_staged(&staged_g, &dynamic).unwrap();
            assert!(
                unstaged == staged,
                "{variant} decode: staged output differs from unstaged"
            );
        }
    });
}

/// The PR 3 tentpole pin: paged decode (block-table gather, in-place
/// page writes) must be BIT-IDENTICAL to contiguous staged decode on
/// the serving graphs for fp, W8A8, and W4A8-fast — same logits for
/// every active row, and the K/V rows written through the block table
/// equal the contiguous output caches position for position.  Block
/// tables are deliberately shuffled (non-contiguous ids) and one batch
/// row is left idle to exercise the masking.
#[test]
fn prop_paged_decode_bit_identical_to_contiguous() {
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    Prop::new("paged == contiguous (decode)").cases(2).check(|rng| {
        let mut rt =
            Runtime::with_backend("artifacts", BackendKind::Native).unwrap();
        let info = rt.manifest.model("tiny3m").unwrap().clone();
        let group = rt.manifest.group_size;
        let (nl, nh, dh) = (info.n_layers, info.n_heads, info.head_dim);
        let smax = info.max_seq;
        for variant in ["fp", "w8a8", "w4a8_fast"] {
            let ckpt = random_checkpoint(&info, rng);
            let qw = model::quantize_checkpoint(
                &ckpt,
                None,
                &QuantRecipe::vanilla_w4(),
                variant,
                group,
            )
            .unwrap();
            let weights: Vec<runtime::Literal> = qw
                .tensors
                .iter()
                .map(|t| runtime::literal_from_st(t).unwrap())
                .collect();
            let pairs: Vec<(&str, &runtime::Literal)> = qw
                .names
                .iter()
                .map(String::as_str)
                .zip(weights.iter())
                .collect();
            let graph = format!("tiny3m_{variant}_decode_b4");
            let staged = rt.stage(&graph, &pairs).unwrap();

            // batch of 4 with one idle row; random per-row history
            let b = 4usize;
            let idle = (rng.next_u64() % b as u64) as usize;
            let mut lens = [0usize; 4];
            let mut token = [0i32; 4];
            for bi in 0..b {
                if bi != idle {
                    lens[bi] = 1 + (rng.next_u64() % 20) as usize;
                    token[bi] =
                        rng.range(3, info.vocab as i64 - 1) as i32;
                }
            }
            let pos: Vec<i32> =
                lens.iter().map(|&l| l as i32).collect();

            // shuffled, non-contiguous block tables over a shared pool
            let bs = 8usize;
            let n_blocks = 64usize;
            let mut ids: Vec<u32> = (0..n_blocks as u32).collect();
            for i in (1..ids.len()).rev() {
                let j =
                    (rng.next_u64() % (i as u64 + 1)) as usize;
                ids.swap(i, j);
            }
            let mut pool = KvBlockPool::new(n_blocks, bs, nl, nh, dh);
            let mut tables: Vec<Vec<u32>> = vec![Vec::new(); b];
            let mut cursor = 0usize;
            for bi in 0..b {
                if bi == idle {
                    continue;
                }
                // room for history AND the write at pos
                let need = (lens[bi] + 1).div_ceil(bs).max(1);
                tables[bi] = ids[cursor..cursor + need].to_vec();
                cursor += need;
            }

            // random history, laid out contiguously AND scattered into
            // the pages (identical values, different homes)
            let row_len = nh * smax * dh;
            let mut k_host: Vec<Vec<f32>> =
                (0..nl).map(|_| vec![0f32; b * row_len]).collect();
            let mut v_host: Vec<Vec<f32>> =
                (0..nl).map(|_| vec![0f32; b * row_len]).collect();
            for l in 0..nl {
                for bi in 0..b {
                    for h in 0..nh {
                        for p in 0..lens[bi] {
                            let off = bi * row_len
                                + (h * smax + p) * dh;
                            for t in 0..dh {
                                k_host[l][off + t] =
                                    rng.normal_f32() * 0.1;
                                v_host[l][off + t] =
                                    rng.normal_f32() * 0.1;
                            }
                        }
                    }
                }
                for bi in 0..b {
                    if bi == idle {
                        continue;
                    }
                    pool.scatter_row(
                        l,
                        &tables[bi],
                        lens[bi],
                        smax,
                        &k_host[l][bi * row_len..(bi + 1) * row_len],
                        &v_host[l][bi * row_len..(bi + 1) * row_len],
                    )
                    .unwrap();
                }
            }

            // contiguous reference: staged decode on the full caches
            let kv_shape = [b, nh, smax, dh];
            let tok_l = runtime::literal_i32(&[b], &token).unwrap();
            let pos_l = runtime::literal_i32(&[b], &pos).unwrap();
            let mut caches: Vec<runtime::Literal> = Vec::new();
            for l in 0..nl {
                caches.push(
                    runtime::literal_f32(&kv_shape, &k_host[l]).unwrap(),
                );
            }
            for l in 0..nl {
                caches.push(
                    runtime::literal_f32(&kv_shape, &v_host[l]).unwrap(),
                );
            }
            let mut dynamic: Vec<&runtime::Literal> = vec![&tok_l, &pos_l];
            dynamic.extend(caches.iter());
            let contig = rt.run_staged(&staged, &dynamic).unwrap();
            let contig_logits = contig[0].to_vec::<f32>().unwrap();

            // paged run on the same staged weights
            let tbl: Vec<&[u32]> =
                tables.iter().map(|t| t.as_slice()).collect();
            let paged_out = rt
                .run_decode_paged(&staged, &token, &pos, &mut pool, &tbl)
                .unwrap();
            let paged_logits = paged_out.to_vec::<f32>().unwrap();

            let v = info.vocab;
            for bi in 0..b {
                if bi == idle {
                    continue;
                }
                assert!(
                    contig_logits[bi * v..(bi + 1) * v]
                        == paged_logits[bi * v..(bi + 1) * v],
                    "{variant} row {bi}: paged logits differ from \
                     contiguous"
                );
            }

            // the K/V rows written through the table must equal the
            // contiguous output caches at positions 0..=pos
            for l in 0..nl {
                let kc = contig[1 + l].as_slice::<f32>().unwrap();
                let vc = contig[1 + nl + l].as_slice::<f32>().unwrap();
                for bi in 0..b {
                    if bi == idle {
                        continue;
                    }
                    let (gk, gv) = pool
                        .gather_row(l, &tables[bi], lens[bi] + 1, smax)
                        .unwrap();
                    for h in 0..nh {
                        for p in 0..=lens[bi] {
                            for t in 0..dh {
                                let gi = (h * smax + p) * dh + t;
                                let ci = bi * row_len + gi;
                                assert!(
                                    gk[gi] == kc[ci]
                                        && gv[gi] == vc[ci],
                                    "{variant} layer {l} row {bi} \
                                     pos {p}: paged K/V differs"
                                );
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Int8 KV (the PR 9 tentpole) against the fp32 reference on one
/// decode step: logits must TRACK the fp pool (quantization noise,
/// not garbage — relative L2 under a loose bound, all finite), and
/// `kv_bytes_moved` must count the bytes ACTUALLY stored — 4 bytes
/// per element on the fp32 pool, 1 on the int8 pool (the satellite-3
/// accounting fix: the counter used to assume fp32 width).
#[test]
fn prop_int8_paged_decode_tracks_fp_and_counts_stored_bytes() {
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    Prop::new("int8 paged decode").cases(2).check(|rng| {
        let mut rt =
            Runtime::with_backend("artifacts", BackendKind::Native)
                .unwrap();
        let info = rt.manifest.model("tiny3m").unwrap().clone();
        let group = rt.manifest.group_size;
        let (nl, nh, dh) = (info.n_layers, info.n_heads, info.head_dim);
        let smax = info.max_seq;
        let ckpt = random_checkpoint(&info, rng);
        let qw = model::quantize_checkpoint(
            &ckpt,
            None,
            &QuantRecipe::vanilla_w4(),
            "fp",
            group,
        )
        .unwrap();
        let weights: Vec<runtime::Literal> = qw
            .tensors
            .iter()
            .map(|t| runtime::literal_from_st(t).unwrap())
            .collect();
        let pairs: Vec<(&str, &runtime::Literal)> = qw
            .names
            .iter()
            .map(String::as_str)
            .zip(weights.iter())
            .collect();
        let staged =
            rt.stage("tiny3m_fp_decode_b4", &pairs).unwrap();

        let b = 4usize;
        let mut lens = [0usize; 4];
        let mut token = [0i32; 4];
        for bi in 0..b {
            lens[bi] = 1 + (rng.next_u64() % 20) as usize;
            token[bi] = rng.range(3, info.vocab as i64 - 1) as i32;
        }
        let pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
        let bs = 8usize;
        let n_blocks = 32usize;
        let mut tables: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut cursor = 0u32;
        for bi in 0..b {
            let need = (lens[bi] + 1).div_ceil(bs).max(1) as u32;
            tables[bi] = (cursor..cursor + need).collect();
            cursor += need;
        }
        let tbl: Vec<&[u32]> =
            tables.iter().map(|t| t.as_slice()).collect();

        // identical random history scattered into both pools (the
        // int8 pool quantizes on scatter)
        let mut pool_f =
            KvBlockPool::new(n_blocks, bs, nl, nh, dh);
        let mut pool_q = KvBlockPool::with_dtype(
            n_blocks,
            bs,
            nl,
            nh,
            dh,
            KvDtype::Int8,
        );
        let row_len = nh * smax * dh;
        for l in 0..nl {
            for bi in 0..b {
                let mut k_row = vec![0f32; row_len];
                let mut v_row = vec![0f32; row_len];
                for h in 0..nh {
                    for p in 0..lens[bi] {
                        for t in 0..dh {
                            let off = (h * smax + p) * dh + t;
                            k_row[off] = rng.normal_f32() * 0.1;
                            v_row[off] = rng.normal_f32() * 0.1;
                        }
                    }
                }
                pool_f
                    .scatter_row(
                        l, &tables[bi], lens[bi], smax, &k_row, &v_row,
                    )
                    .unwrap();
                pool_q
                    .scatter_row(
                        l, &tables[bi], lens[bi], smax, &k_row, &v_row,
                    )
                    .unwrap();
            }
        }

        let before_f = rt.staging_stats().kv_bytes_moved;
        let out_f = rt
            .run_decode_paged(&staged, &token, &pos, &mut pool_f, &tbl)
            .unwrap();
        let moved_f = rt.staging_stats().kv_bytes_moved - before_f;
        let before_q = rt.staging_stats().kv_bytes_moved;
        let out_q = rt
            .run_decode_paged(&staged, &token, &pos, &mut pool_q, &tbl)
            .unwrap();
        let moved_q = rt.staging_stats().kv_bytes_moved - before_q;

        // satellite 3: actual stored bytes, not assumed-fp32 width
        let per_row = (2 * nh * dh) as u64;
        assert_eq!(
            moved_f,
            nl as u64 * b as u64 * per_row * 4,
            "fp32 pool must count 4 bytes per stored element"
        );
        assert_eq!(
            moved_q,
            nl as u64 * b as u64 * per_row,
            "int8 pool must count 1 byte per stored element"
        );

        // quality: int8 logits track fp (noise, not garbage)
        let lf = out_f.to_vec::<f32>().unwrap();
        let lq = out_q.to_vec::<f32>().unwrap();
        assert_eq!(lf.len(), lq.len());
        let mut num = 0f64;
        let mut den = 0f64;
        for (a, q) in lf.iter().zip(lq.iter()) {
            assert!(q.is_finite(), "int8 decode produced non-finite");
            num += ((a - q) as f64).powi(2);
            den += (*a as f64).powi(2);
        }
        let rel = (num / den.max(1e-12)).sqrt();
        assert!(
            rel < 0.25,
            "int8 KV logits diverged from fp: rel L2 {rel:.4}"
        );
    });
}

/// Staged GEMM graphs (packed int4 payloads staged once, conversion
/// still fused in-kernel) must also match unstaged execution bit for
/// bit, across fp, W8A8, and the FastGEMM path.
#[test]
fn prop_staged_gemm_graphs_bit_identical_to_unstaged() {
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    Prop::new("staged == unstaged (gemm)").cases(3).check(|rng| {
        let mut rt =
            Runtime::with_backend("artifacts", BackendKind::Native).unwrap();
        let graphs: Vec<_> = rt
            .manifest
            .gemm_graphs("cpu")
            .into_iter()
            .filter(|g| {
                g.m == 1
                    && ["fp", "w8a8", "w4a8_fast"]
                        .contains(&g.variant.as_str())
            })
            .cloned()
            .collect();
        assert!(!graphs.is_empty(), "cpu gemm shape set missing");
        for gi in &graphs {
            let args = random_gemm_args_with(&gi.params, rng).unwrap();
            let n_dyn = gi.dynamic_param_count(&rt.manifest).unwrap();
            let full: Vec<&runtime::Literal> = args.iter().collect();
            let unstaged = rt.run_literal_refs(&gi.name, &full).unwrap();
            let pairs: Vec<(&str, &runtime::Literal)> = gi.params[n_dyn..]
                .iter()
                .map(|p| p.name.as_str())
                .zip(args[n_dyn..].iter())
                .collect();
            let staged_g = rt.stage(&gi.name, &pairs).unwrap();
            let dynamic: Vec<&runtime::Literal> =
                args[..n_dyn].iter().collect();
            let staged = rt.run_staged(&staged_g, &dynamic).unwrap();
            assert!(
                unstaged == staged,
                "{}: staged gemm output differs from unstaged",
                gi.name
            );
        }
    });
}

// ------------------------------------------- native backend interop

/// The engine-path interop contract at the tiny3m weight shapes: for
/// every int4 nibble value, running the packed weights through the
/// native FastGEMM kernel (`unpack_x16` + /16 dequant epilogue) equals
/// the vanilla route (`unpack_int4` to true int4 values, then the plain
/// per-channel epilogue) BIT-EXACTLY.
#[test]
fn prop_fastgemm_epilogue_matches_unpacked_route_bit_exact() {
    use odyssey::runtime::native::{gemm_w4a8_fast, gemm_w8a8};

    // (K, N) pairs used by the tiny3m matrices: attention, gate/up, down
    let shapes = [(256usize, 256usize), (256, 768), (768, 256)];
    Prop::new("fastgemm epilogue interop").cases(3).check(|rng| {
        for &(k, n) in &shapes {
            let m = 2;
            let x = Tensor::randn(&[m, k], rng.next_u64());
            let (xq, s_a) = scale::quant_act_per_token(&x).unwrap();
            // int4 weights covering ALL 16 nibble values: first rows
            // sweep -8..=7 in every column, the rest are random
            let mut q = Tensor::<i8>::zeros(&[k, n]);
            for i in 0..k {
                for j in 0..n {
                    let v = if i < 16 {
                        i as i32 - 8
                    } else {
                        rng.range(-8, 8) as i32
                    };
                    q.set2(i, j, v as i8);
                }
            }
            let s_w: Vec<f32> =
                (0..n).map(|_| 0.01 + rng.next_f32() * 0.05).collect();
            let p = pack::pack_int4(&q);

            // FastGEMM route: x16 weights, s_w/16 epilogue (inside)
            let fast = gemm_w4a8_fast(&xq, &s_a, &p, &s_w);
            // vanilla route: true int4 values + plain epilogue
            let w4 = pack::unpack_int4(&p);
            assert_eq!(w4, q, "unpack must invert pack");
            let vanilla = gemm_w8a8(&xq, &s_a, &w4, &s_w);

            assert_eq!(
                fast.shape(),
                vanilla.shape(),
                "shape mismatch at ({k},{n})"
            );
            for (i, (a, b)) in fast
                .data()
                .iter()
                .zip(vanilla.data().iter())
                .enumerate()
            {
                assert!(
                    a == b,
                    "({k},{n})[{i}]: fast {a} != vanilla {b} \
                     (must be bit-exact)"
                );
            }
        }
    });
}

// --------------------------------------------- kernel-set dispatch

/// Cross-set dispatch parity: the scalar reference set, the
/// cache-blocked set, and the threadpool-parallel set must produce
/// BIT-IDENTICAL outputs for every GEMM flavor the graph walkers
/// dispatch (`fp`, `w8a8`, `w4a8_fast` packed, `w4a8_fast_pre`
/// pre-unpacked), across ragged shapes that straddle the blocked set's
/// KC=256 / NC=128 tile borders and both parallel partitioning modes
/// (row blocks at large M, column strips at small M).  This is the
/// contract that makes `ODYSSEY_KERNELS` a pure speed knob: token
/// streams cannot depend on it.
#[test]
fn prop_kernel_sets_bit_identical_across_dispatch() {
    use odyssey::kernels::{kernel_set, KernelChoice};

    Prop::new("kernel sets bit-identical").cases(10).check(|rng| {
        // constructed per case: the dispatch handles are Arc'd trait
        // objects, which the panic-capturing prop harness cannot hold
        // across cases (not RefUnwindSafe)
        let sets = [
            kernel_set(KernelChoice::Scalar),
            kernel_set(KernelChoice::Blocked),
            kernel_set(KernelChoice::Parallel),
        ];
        // M from 1 (decode row) to ~20 (prefill slab); K even for the
        // int4 pack, up to 2*KC + change; N past one NC tile
        let m = 1 + (rng.next_u64() % 20) as usize;
        let k = 2 * (1 + (rng.next_u64() % 160) as usize);
        let n = 1 + (rng.next_u64() % 140) as usize;
        let x = Tensor::randn(&[m, k], rng.next_u64());
        let wf = Tensor::randn(&[k, n], rng.next_u64());
        let (xq, s_a) = scale::quant_act_per_token(&x).unwrap();
        let (w8, s_w8) = rtn::rtn_per_channel(&wf, 8, None, None);
        let (w4, s_w4) = rtn::rtn_per_channel(&wf, 4, None, None);
        let wp = pack::pack_int4(&w4);
        let w16 = pack::unpack_x16(&wp);

        let fp: Vec<_> =
            sets.iter().map(|ks| ks.gemm_fp(&x, &wf)).collect();
        let w8a8: Vec<_> = sets
            .iter()
            .map(|ks| ks.gemm_w8a8(&xq, &s_a, &w8, &s_w8))
            .collect();
        let fast: Vec<_> = sets
            .iter()
            .map(|ks| ks.gemm_w4a8_fast(&xq, &s_a, &wp, &s_w4))
            .collect();
        let pre: Vec<_> = sets
            .iter()
            .map(|ks| ks.gemm_w4a8_fast_pre(&xq, &s_a, &w16, &s_w4))
            .collect();
        for (i, ks) in sets.iter().enumerate().skip(1) {
            let who = ks.name();
            assert_eq!(fp[0], fp[i], "({m},{k},{n}) fp: scalar != {who}");
            assert_eq!(
                w8a8[0], w8a8[i],
                "({m},{k},{n}) w8a8: scalar != {who}"
            );
            assert_eq!(
                fast[0], fast[i],
                "({m},{k},{n}) w4a8_fast: scalar != {who}"
            );
            assert_eq!(
                pre[0], pre[i],
                "({m},{k},{n}) w4a8_fast_pre: scalar != {who}"
            );
        }
        // the fused per-tile unpack equals the pre-unpacked route too
        assert_eq!(
            fast[0], pre[0],
            "({m},{k},{n}) fused unpack != pre-unpacked"
        );
    });
}
