//! End-to-end serving stack over real sockets: the HTTP front-end, the
//! engine service thread, and streaming NDJSON responses, all against a
//! synth-checkpoint engine on the native CPU backend.
//!
//! What this suite pins down:
//!
//! * streamed token frames reassemble bit-identical to what
//!   [`EngineHandle::generate`] returns for the same seeded request
//! * concurrent streaming clients each see ordered, gap-free frames
//! * a saturating burst is shed with 429 + `Retry-After` — every
//!   client gets an answer, none hang
//! * strict input validation surfaces as 400s naming the field
//! * oversized declared bodies are rejected before the upload and
//!   `Expect: 100-continue` is answered on a real socket
//! * graceful drain lets in-flight streams finish after `stop` flips
//! * an engine step failure resolves every waiter (blocking AND
//!   streaming) with `FinishReason::Error` instead of hanging them

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use odyssey::coordinator::handle::EngineService;
use odyssey::coordinator::{
    EngineHandle, EngineOptions, FinishReason, GenParams, StreamEvent,
};
use odyssey::formats::json::Json;
use odyssey::quant::QuantRecipe;
use odyssey::runtime::{synth, BackendKind};
use odyssey::server::{Server, ServerOptions};

/// Serialize server/engine construction across tests: the first call
/// synthesizes artifacts, and one engine at a time mirrors production.
fn lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap()
}

fn engine_opts() -> EngineOptions {
    EngineOptions {
        variant: "fp".into(),
        // vanilla: serving tests exercise the stack, not the quantizer
        recipe: QuantRecipe::vanilla_w4(),
        max_queue: 8,
        backend: BackendKind::Native,
        ..Default::default()
    }
}

/// A live server + engine; dropped = stopped, drained, shut down.
struct TestServer {
    addr: SocketAddr,
    handle: EngineHandle,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    svc: Option<EngineService>,
}

fn start(eopts: EngineOptions, sopts: ServerOptions) -> TestServer {
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    let svc = EngineService::spawn(eopts).expect("engine spawn");
    let handle = svc.handle.clone();
    let server = Server::bind("127.0.0.1:0", handle.clone(), sopts)
        .expect("bind");
    let addr = server.local_addr().expect("local addr");
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let join = std::thread::spawn(move || {
        server.run(stop2).expect("server run");
    });
    TestServer { addr, handle, stop, join: Some(join), svc: Some(svc) }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        if let Some(svc) = self.svc.take() {
            svc.shutdown();
        }
    }
}

/// POST and read the whole response (the server closes the connection,
/// so `read_to_string` delimits it).  A read timeout turns a hung
/// connection into an `Err` instead of wedging the test.
fn post(
    addr: SocketAddr,
    path: &str,
    body: &str,
    timeout_s: u64,
) -> anyhow::Result<(u16, Vec<(String, String)>, String)> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(timeout_s)))?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes())?;
    let mut out = String::new();
    s.read_to_string(&mut out)?;
    Ok(split_response(&out))
}

fn split_response(raw: &str) -> (u16, Vec<(String, String)>, String) {
    let mut parts = raw.splitn(2, "\r\n\r\n");
    let head = parts.next().unwrap_or("");
    let body = parts.next().unwrap_or("").to_string();
    let mut lines = head.lines();
    let status = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|c| c.parse().ok())
        .unwrap_or(0);
    let headers = lines
        .filter_map(|l| {
            l.split_once(':').map(|(k, v)| {
                (k.trim().to_ascii_lowercase(), v.trim().to_string())
            })
        })
        .collect();
    (status, headers, body)
}

fn header<'a>(
    headers: &'a [(String, String)],
    name: &str,
) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k.as_str() == name)
        .map(|(_, v)| v.as_str())
}

/// Parse an NDJSON body into frames.
fn parse_frames(body: &str) -> Vec<Json> {
    body.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).expect("frame is valid json"))
        .collect()
}

fn tokens_of(frame: &Json) -> Vec<i32> {
    frame
        .get("tokens")
        .as_arr()
        .expect("frame carries a tokens array")
        .iter()
        .map(|v| v.as_f64().expect("token is a number") as i32)
        .collect()
}

/// Read from the socket until the end of an HTTP header block.
fn read_head_block(s: &mut TcpStream) -> String {
    let mut buf = Vec::new();
    let mut b = [0u8; 1];
    while !buf.ends_with(b"\r\n\r\n") {
        match s.read(&mut b) {
            Ok(1) => buf.push(b[0]),
            _ => break,
        }
    }
    String::from_utf8_lossy(&buf).into_owned()
}

fn finish_name(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Eos => "eos",
        FinishReason::MaxTokens => "length",
        FinishReason::Stop => "stop",
        FinishReason::Rejected => "rejected",
        FinishReason::Error => "error",
    }
}

#[test]
fn streamed_frames_match_blocking_generate() {
    let _g = lock();
    let ts = start(engine_opts(), ServerOptions::default());
    let prompt: Vec<i32> = (0..24).map(|i| 3 + (i * 7) % 490).collect();
    let params =
        GenParams { max_new_tokens: 8, seed: 7, ..Default::default() };
    let blocking = ts
        .handle
        .generate(prompt.clone(), params)
        .expect("blocking generate");

    let toks = prompt
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",");
    let body = format!(
        r#"{{"tokens":[{toks}],"max_new_tokens":8,"seed":7,"stream":true}}"#
    );
    let (status, headers, resp) =
        post(ts.addr, "/generate", &body, 60).expect("stream request");
    assert_eq!(status, 200, "body: {resp}");
    let ct = header(&headers, "content-type").unwrap_or_default();
    assert!(ct.contains("ndjson"), "content-type: {ct}");
    assert!(
        header(&headers, "content-length").is_none(),
        "streaming responses are connection-close delimited"
    );

    let frames = parse_frames(&resp);
    let (done, token_frames) =
        frames.split_last().expect("at least a done frame");
    assert_eq!(done.get("done").as_bool(), Some(true));
    let done_tokens = tokens_of(done);
    let streamed: Vec<i32> = token_frames
        .iter()
        .enumerate()
        .map(|(i, f)| {
            assert_eq!(
                f.get("index").as_f64(),
                Some(i as f64),
                "frames arrive in order with no gaps"
            );
            f.get("token").as_f64().expect("token number") as i32
        })
        .collect();
    assert_eq!(
        streamed, done_tokens,
        "per-token frames reassemble to the final result"
    );
    assert_eq!(
        done_tokens, blocking.tokens,
        "streamed tokens are bit-identical to the blocking call"
    );
    assert_eq!(
        done.get("finish").as_str(),
        Some(finish_name(blocking.finish))
    );
}

#[test]
fn concurrent_streaming_clients_each_get_ordered_frames() {
    let _g = lock();
    let ts = start(engine_opts(), ServerOptions::default());
    let addr = ts.addr;
    let clients: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"tokens":[1,3,{},{},3,80],"max_new_tokens":6,"stream":true}}"#,
                    140 + i,
                    150 + i
                );
                post(addr, "/generate", &body, 60)
            })
        })
        .collect();
    for c in clients {
        let (status, _h, resp) =
            c.join().unwrap().expect("client got a response");
        assert_eq!(status, 200, "body: {resp}");
        let frames = parse_frames(&resp);
        let (done, token_frames) =
            frames.split_last().expect("at least a done frame");
        assert_eq!(done.get("done").as_bool(), Some(true));
        let done_tokens = tokens_of(done);
        assert_eq!(
            token_frames.len(),
            done_tokens.len(),
            "one frame per generated token"
        );
        for (i, f) in token_frames.iter().enumerate() {
            assert_eq!(f.get("index").as_f64(), Some(i as f64));
            assert_eq!(
                f.get("token").as_f64().map(|t| t as i32),
                Some(done_tokens[i])
            );
        }
    }
}

#[test]
fn parallel_sampling_streams_branch_tagged_frames() {
    let _g = lock();
    let ts = start(engine_opts(), ServerOptions::default());
    let toks = (0..24)
        .map(|i| (3 + (i * 7) % 490).to_string())
        .collect::<Vec<_>>()
        .join(",");
    let body = format!(
        r#"{{"tokens":[{toks}],"max_new_tokens":6,"n":2,"temperature":0.8,"seed":11,"stream":true}}"#
    );
    let (status, _h, resp) =
        post(ts.addr, "/generate", &body, 60).expect("stream request");
    assert_eq!(status, 200, "body: {resp}");
    let frames = parse_frames(&resp);
    let (done, token_frames) =
        frames.split_last().expect("at least a done frame");
    assert_eq!(done.get("done").as_bool(), Some(true));

    // the terminal frame carries one completion per branch, and its
    // top-level tokens/finish mirror branch 0
    let completions = done
        .get("completions")
        .as_arr()
        .expect("n=2 result carries a completions array");
    assert_eq!(completions.len(), 2);
    assert_eq!(tokens_of(done), tokens_of(&completions[0]));

    // sampled n>1 results carry best-of-n ranking: a per-branch
    // sum_logprob and a top-level `best` index into completions
    let scores: Vec<f64> = completions
        .iter()
        .map(|c| {
            c.get("sum_logprob")
                .as_f64()
                .expect("each completion carries sum_logprob")
        })
        .collect();
    let best = done
        .get("best")
        .as_f64()
        .expect("sampled n=2 result carries best") as usize;
    assert!(best < 2, "best indexes a completion");
    assert!(
        scores.iter().all(|&s| scores[best] >= s),
        "best must have the highest sum_logprob ({scores:?})"
    );

    // token frames are branch-tagged; per branch they arrive ordered
    // and gap-free and reassemble to that branch's completion
    let mut per_branch: Vec<Vec<i32>> = vec![Vec::new(), Vec::new()];
    for f in token_frames {
        let b = f.get("branch").as_f64().expect("frame carries branch")
            as usize;
        assert!(b < 2, "branch index in range");
        assert_eq!(
            f.get("index").as_f64(),
            Some(per_branch[b].len() as f64),
            "per-branch frames are ordered with no gaps"
        );
        per_branch[b]
            .push(f.get("token").as_f64().expect("token number") as i32);
    }
    for (b, c) in completions.iter().enumerate() {
        assert_eq!(
            per_branch[b],
            tokens_of(c),
            "branch {b} frames reassemble to its completion"
        );
    }
}

#[test]
fn saturating_burst_sheds_with_429_and_no_hangs() {
    let _g = lock();
    let eopts = EngineOptions { max_queue: 1, ..engine_opts() };
    let sopts = ServerOptions { workers: 8, ..Default::default() };
    let ts = start(eopts, sopts);
    let addr = ts.addr;
    let n = 16;
    let clients: Vec<_> = (0..n)
        .map(|i| {
            std::thread::spawn(move || {
                let toks = (0..48)
                    .map(|j| (3 + (i * 31 + j * 7) % 490).to_string())
                    .collect::<Vec<_>>()
                    .join(",");
                let body = format!(
                    r#"{{"tokens":[{toks}],"max_new_tokens":12}}"#
                );
                post(addr, "/generate", &body, 60)
            })
        })
        .collect();
    let mut ok = 0;
    let mut rejected = 0;
    for c in clients {
        let (status, headers, resp) = c
            .join()
            .unwrap()
            .expect("every client gets an answer — no hangs");
        match status {
            200 => ok += 1,
            429 => {
                rejected += 1;
                let ra = header(&headers, "retry-after")
                    .expect("429 carries Retry-After");
                assert!(
                    ra.parse::<f64>().is_ok(),
                    "Retry-After is numeric: {ra}"
                );
            }
            other => panic!("unexpected status {other}: {resp}"),
        }
    }
    assert_eq!(ok + rejected, n);
    assert!(ok >= 1, "the queue still serves someone");
    assert!(
        rejected >= 1,
        "a 16-deep burst over max_queue=1 must shed load"
    );
}

#[test]
fn validation_errors_name_the_field_over_http() {
    let _g = lock();
    let ts = start(engine_opts(), ServerOptions::default());
    // regression: non-integer entries used to be silently dropped
    let (status, _h, body) =
        post(ts.addr, "/generate", r#"{"tokens":[1,"a",2]}"#, 30)
            .unwrap();
    assert_eq!(status, 400);
    assert!(body.contains("tokens[1]"), "got: {body}");
    // regression: zero used to be silently clamped to 1
    let (status, _h, body) = post(
        ts.addr,
        "/generate",
        r#"{"tokens":[5],"max_new_tokens":0}"#,
        30,
    )
    .unwrap();
    assert_eq!(status, 400);
    assert!(body.contains("max_new_tokens"), "got: {body}");
    // the streaming path validates identically (plain 400, no frames)
    let (status, _h, body) = post(
        ts.addr,
        "/generate",
        r#"{"tokens":[5],"max_new_tokens":0,"stream":true}"#,
        30,
    )
    .unwrap();
    assert_eq!(status, 400);
    assert!(body.contains("max_new_tokens"), "got: {body}");
}

#[test]
fn oversize_rejected_early_and_expect_continue_answered() {
    let _g = lock();
    let ts = start(engine_opts(), ServerOptions::default());

    // declared length over the cap: 413 from the header alone — the
    // body is never uploaded (we never send it)
    let mut s = TcpStream::connect(ts.addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(
        b"POST /generate HTTP/1.1\r\nHost: t\r\n\
          Content-Length: 20000000\r\nExpect: 100-continue\r\n\r\n",
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    assert!(out.starts_with("HTTP/1.1 413"), "got: {out}");
    assert!(
        !out.contains("HTTP/1.1 100"),
        "no continue invitation for a condemned request"
    );

    // small body with Expect: the server invites the upload first
    let body = r#"{"tokens":[1,3,140],"max_new_tokens":2}"#;
    let mut s = TcpStream::connect(ts.addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(
        format!(
            "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
             Expect: 100-continue\r\n\r\n",
            body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    let interim = read_head_block(&mut s);
    assert!(interim.starts_with("HTTP/1.1 100"), "got: {interim}");
    s.write_all(body.as_bytes()).unwrap();
    let mut rest = String::new();
    s.read_to_string(&mut rest).unwrap();
    assert!(rest.starts_with("HTTP/1.1 200"), "got: {rest}");
}

#[test]
fn graceful_drain_completes_inflight_streams() {
    let _g = lock();
    let sopts =
        ServerOptions { drain_wait_s: 30.0, ..Default::default() };
    let ts = start(engine_opts(), sopts);

    // open streams and read each response head: once the 200 head is
    // on the wire the request is provably resident in the server
    let mut socks: Vec<TcpStream> = (0..3)
        .map(|i| {
            let body = format!(
                r#"{{"tokens":[1,3,{},80],"max_new_tokens":24,"stream":true}}"#,
                140 + i
            );
            let mut s = TcpStream::connect(ts.addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
            s.write_all(
                format!(
                    "POST /generate HTTP/1.1\r\nHost: t\r\n\
                     Content-Length: {}\r\n\r\n{}",
                    body.len(),
                    body
                )
                .as_bytes(),
            )
            .unwrap();
            s
        })
        .collect();
    for s in &mut socks {
        let head = read_head_block(s);
        assert!(head.starts_with("HTTP/1.1 200"), "got: {head}");
    }

    // close the doors mid-stream; residents must still finish
    ts.stop.store(true, Ordering::Relaxed);
    for mut s in socks {
        let mut rest = String::new();
        s.read_to_string(&mut rest)
            .expect("in-flight stream finishes during drain");
        let frames = parse_frames(&rest);
        let done = frames.last().expect("frames delivered during drain");
        assert_eq!(
            done.get("done").as_bool(),
            Some(true),
            "drain delivers the terminal frame"
        );
    }
}

#[test]
fn engine_step_failure_resolves_all_waiters_instead_of_hanging() {
    let _g = lock();
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    // the backend errors on the third engine step; with eos disabled no
    // request can finish in two steps, so every caller must be failed
    let svc = EngineService::spawn(EngineOptions {
        fail_step_after: Some(3),
        ..engine_opts()
    })
    .expect("engine spawn");
    let handle = svc.handle.clone();

    let done = Arc::new(AtomicUsize::new(0));
    let results = Arc::new(Mutex::new(Vec::new()));
    let mut joins = Vec::new();
    for i in 0..4i32 {
        let h = handle.clone();
        let d = Arc::clone(&done);
        let r = Arc::clone(&results);
        joins.push(std::thread::spawn(move || {
            let res = h.generate(
                (0..16).map(|j| 3 + (i * 13 + j) % 490).collect(),
                GenParams {
                    max_new_tokens: 8,
                    eos: None,
                    ..Default::default()
                },
            );
            r.lock().unwrap().push(res);
            d.fetch_add(1, Ordering::SeqCst);
        }));
    }

    // a streaming caller rides along, consumed with bounded waits
    let rx = handle
        .generate_streaming(
            vec![3, 4, 5, 6],
            GenParams { max_new_tokens: 8, eos: None, ..Default::default() },
        )
        .expect("submit stream");
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut stream_done = None;
    while Instant::now() < deadline {
        match rx.recv_timeout(Duration::from_millis(200)) {
            Ok(StreamEvent::Done(res)) => {
                stream_done = Some(res);
                break;
            }
            Ok(StreamEvent::Token { .. }) => {}
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    let stream_done = stream_done
        .expect("streaming waiter gets a Done frame, not a hang");
    assert_eq!(stream_done.finish, FinishReason::Error);

    // bounded wait: before the fix, these callers hung forever
    let deadline = Instant::now() + Duration::from_secs(30);
    while done.load(Ordering::SeqCst) < 4 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(
        done.load(Ordering::SeqCst),
        4,
        "every blocking caller resolves after the step failure"
    );
    for j in joins {
        let _ = j.join();
    }
    for res in results.lock().unwrap().iter() {
        let res = res.as_ref().expect("generate returns a result");
        assert_eq!(
            res.finish,
            FinishReason::Error,
            "aborted requests carry FinishReason::Error"
        );
    }
    svc.shutdown();
}
