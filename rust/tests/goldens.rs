//! Cross-language golden tests: the rust quant core replayed against the
//! python reference outputs (artifacts/goldens.safetensors, emitted by
//! compile/goldens.py from fixed seeds).
//!
//! Integer outputs must match BIT-FOR-BIT; float scales to 1e-5.  These
//! are the contracts that make the rust quantizer interchangeable with
//! the python one.
//!
//! The goldens file can only come from the python side (fixed-seed numpy
//! outputs), so when it is absent — e.g. a clean checkout running on the
//! native backend with synthetic artifacts — every test here SKIPS
//! rather than fails.  Run `python -m compile.aot` to enable them.

use std::sync::atomic::{AtomicUsize, Ordering};

use odyssey::formats::safetensors::SafeTensors;
use odyssey::quant::{awq, gptq, lwc, pack, rtn, scale, smoothquant,
                     GptqConfig};
use odyssey::tensor::Tensor;

/// Running count of tests skipped for missing goldens, so a CI log
/// shows "skipped: ..." lines with an explicit tally instead of the
/// suite silently reading as all-passed.
static SKIPPED: AtomicUsize = AtomicUsize::new(0);

fn goldens() -> Option<SafeTensors> {
    if !std::path::Path::new("artifacts/goldens.safetensors").exists() {
        let n = SKIPPED.fetch_add(1, Ordering::SeqCst) + 1;
        eprintln!(
            "skipped: artifacts/goldens.safetensors absent (python AOT \
             pass not run; `python -m compile.aot` emits it) — golden \
             test skip #{n} in this run"
        );
        return None;
    }
    Some(
        SafeTensors::load("artifacts/goldens.safetensors")
            .expect("goldens file unreadable"),
    )
}

/// Fetch the goldens or skip the calling test (with an explicit
/// `skipped: <reason>` line on stderr — a skip must never be silent).
macro_rules! goldens_or_skip {
    () => {
        match goldens() {
            Some(g) => g,
            None => return,
        }
    };
}

fn t_f32(g: &SafeTensors, name: &str) -> Tensor<f32> {
    g.get(name).unwrap().to_f32().unwrap()
}

fn t_i8(g: &SafeTensors, name: &str) -> Tensor<i8> {
    g.get(name).unwrap().to_i8().unwrap()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn rtn_per_channel_matches_python() {
    let g = goldens_or_skip!();
    let w = t_f32(&g, "in.w");
    for bits in [4u32, 8] {
        let (q, s) = rtn::rtn_per_channel(&w, bits, None, None);
        let qp = t_i8(&g, &format!("rtn_pc{bits}.q"));
        let sp = t_f32(&g, &format!("rtn_pc{bits}.s"));
        assert_eq!(q.data(), qp.data(), "rtn_pc{bits} ints");
        assert_close(&s, sp.data(), 1e-6, "rtn scales");
    }
}

#[test]
fn rtn_per_group_matches_python() {
    let g = goldens_or_skip!();
    let w = t_f32(&g, "in.w");
    let (q, s) = rtn::rtn_per_group(&w, 8, 4);
    assert_eq!(q.data(), t_i8(&g, "rtn_g8.q").data());
    assert_close(s.data(), t_f32(&g, "rtn_g8.s").data(), 1e-6, "g scales");
}

#[test]
fn lwc_grid_matches_python() {
    let g = goldens_or_skip!();
    let w = t_f32(&g, "in.w");
    let r = lwc::lwc(&w, 4);
    assert_close(&r.gamma, t_f32(&g, "lwc.gamma").data(), 1e-6, "gamma");
    assert_close(&r.beta, t_f32(&g, "lwc.beta").data(), 1e-6, "beta");
    let (q, s) =
        rtn::rtn_per_channel(&w, 4, Some(&r.gamma), Some(&r.beta));
    assert_eq!(q.data(), t_i8(&g, "lwc.q").data(), "lwc-quantized ints");
    assert_close(&s, t_f32(&g, "lwc.s").data(), 1e-6, "lwc scales");
}

#[test]
fn gptq_matches_python() {
    let g = goldens_or_skip!();
    let w = t_f32(&g, "in.w");
    let h = t_f32(&g, "in.h");
    let s_lwc = t_f32(&g, "lwc.s");
    let res = gptq::gptq_quantize(
        &w,
        &h,
        &GptqConfig::default(),
        Some(s_lwc.data()),
    )
    .unwrap();
    let qp = t_i8(&g, "gptq.q");
    // GPTQ accumulates float error-feedback; rust (f64, same order)
    // matches python bit-for-bit
    assert_eq!(res.q.data(), qp.data(), "gptq ints");
    assert_close(&res.scales, t_f32(&g, "gptq.s").data(), 1e-6, "gptq s");
}

#[test]
fn gptq_act_order_matches_python() {
    let g = goldens_or_skip!();
    let w = t_f32(&g, "in.w");
    let h = t_f32(&g, "in.h");
    let res = gptq::gptq_quantize(
        &w,
        &h,
        &GptqConfig { act_order: true, ..Default::default() },
        None,
    )
    .unwrap();
    let perm_py = g.get("gptq_ro.perm").unwrap().to_i64().unwrap();
    let perm: Vec<i64> =
        res.perm.unwrap().iter().map(|&v| v as i64).collect();
    assert_eq!(perm, perm_py.data(), "ro permutation");
    assert_eq!(res.q.data(), t_i8(&g, "gptq_ro.q").data(), "ro ints");
}

#[test]
fn gptq_grouped_matches_python() {
    let g = goldens_or_skip!();
    let w = t_f32(&g, "in.w");
    let h = t_f32(&g, "in.h");
    let res = gptq::gptq_quantize(
        &w,
        &h,
        &GptqConfig { group: 8, ..Default::default() },
        None,
    )
    .unwrap();
    assert_eq!(res.q.data(), t_i8(&g, "gptq_g8.q").data(), "g8 ints");
    assert_close(
        &res.scales,
        t_f32(&g, "gptq_g8.s").data(),
        1e-6,
        "g8 scales",
    );
}

#[test]
fn packing_matches_python() {
    let g = goldens_or_skip!();
    let q = t_i8(&g, "lwc.q");
    let p = pack::pack_int4(&q);
    let pp = g.get("pack.p").unwrap().to_u8().unwrap();
    assert_eq!(p.data(), pp.data(), "packed bytes");
    let x16 = pack::unpack_x16(&p);
    let xp = t_i8(&g, "pack.unpacked_x16");
    assert_eq!(x16.data(), xp.data(), "x16 unpack");
}

#[test]
fn smoothquant_scales_match_python() {
    let g = goldens_or_skip!();
    let w = t_f32(&g, "in.w");
    let absmax = t_f32(&g, "in.absmax");
    let s = smoothquant::smoothquant_scales(absmax.data(), &w, 0.5);
    assert_close(&s, t_f32(&g, "sq.scales").data(), 1e-5, "sq scales");
}

#[test]
fn awq_scales_match_python() {
    let g = goldens_or_skip!();
    let w = t_f32(&g, "in.w");
    let x = t_f32(&g, "in.x");
    let absmean = t_f32(&g, "in.absmean");
    let res = awq::awq_search(absmean.data(), &w, &x, 4, 8);
    assert_close(
        &res.scales,
        t_f32(&g, "awq.scales").data(),
        1e-4,
        "awq scales",
    );
}

#[test]
fn act_quant_matches_python() {
    let g = goldens_or_skip!();
    let x = t_f32(&g, "in.x").slice_rows(0, 8);
    let (q, s) = scale::quant_act_per_token(&x).unwrap();
    assert_eq!(q.data(), t_i8(&g, "actq.q").data(), "act ints");
    assert_close(&s, t_f32(&g, "actq.s").data(), 1e-6, "act scales");
}

#[test]
fn asym_matches_python() {
    let g = goldens_or_skip!();
    let w = t_f32(&g, "in.w");
    let (u, s, z) = rtn::rtn_per_channel_asym(&w, 4);
    assert_eq!(
        u.data(),
        g.get("asym.u").unwrap().to_u8().unwrap().data(),
        "asym uints"
    );
    assert_close(&s, t_f32(&g, "asym.s").data(), 1e-6, "asym scales");
    let zp = g.get("asym.z").unwrap().to_i32().unwrap();
    assert_eq!(&z, zp.data(), "zero points");
}
