//! Engine / coordinator integration on the native CPU backend:
//! generation correctness, continuous batching, determinism, shedding,
//! and the thread-safe service front door.
//!
//! Artifacts are synthesized on first use (`runtime::synth`), so these
//! tests run from a clean checkout with no python AOT pass.

use std::sync::{Mutex, OnceLock};

use odyssey::coordinator::handle::EngineService;
use odyssey::coordinator::request::FinishReason;
use odyssey::coordinator::{Engine, EngineOptions, GenParams, Request};
use odyssey::quant::QuantRecipe;
use odyssey::runtime::{synth, BackendKind, KvDtype};

/// Serialize engine construction: engines are cheap on the native
/// backend but the first call synthesizes the artifact set, and keeping
/// the old one-engine-at-a-time topology mirrors production (the engine
/// is !Sync and owned by one thread).
fn with_engine<R>(f: impl FnOnce(&mut Engine) -> R) -> R {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let _guard = LOCK.get_or_init(|| Mutex::new(())).lock().unwrap();
    synth::ensure_artifacts("artifacts").expect("synthesize artifacts");
    let mut engine = Engine::new(opts("fp")).expect("engine construction");
    engine.reset_metrics();
    f(&mut engine)
}

fn opts(variant: &str) -> EngineOptions {
    EngineOptions {
        variant: variant.into(),
        // vanilla: engine tests exercise SERVING, not quantizer quality
        recipe: if variant == "w8a8" {
            QuantRecipe::smoothquant_w8()
        } else {
            QuantRecipe::vanilla_w4()
        },
        max_queue: 8,
        // the point of this suite: everything runs through the native
        // CPU backend, no PJRT/XLA anywhere
        backend: BackendKind::Native,
        ..Default::default()
    }
}

fn prompt(seed: i32, len: usize) -> Vec<i32> {
    (0..len).map(|i| 3 + ((seed + i as i32 * 7) % 500)).collect()
}

#[test]
fn generates_requested_tokens() {
    with_engine(|engine| {
    engine.submit(Request::new(
        1,
        prompt(1, 12),
        GenParams { max_new_tokens: 5, eos: None, ..Default::default() },
    ));
    let results = engine.run_until_idle().unwrap();
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].tokens.len(), 5);
    assert_eq!(results[0].finish, FinishReason::MaxTokens);
    assert!(results[0].ttft_s > 0.0);
    assert!(results[0].total_s >= results[0].ttft_s);
    // tokens must be valid vocab ids
    let vocab = engine.info().vocab as i32;
    assert!(results[0].tokens.iter().all(|&t| (0..vocab).contains(&t)));
    });
}

#[test]
fn w4a8_fast_generates_end_to_end_on_native_backend() {
    // the acceptance path: the paper's FastGEMM W4A8 variant serving
    // tokens through the pure-Rust backend, no AOT artifacts involved
    with_engine(|_shared| {
        let mut o = opts("w4a8_fast");
        // step-count asserts below assume one token per decode pass
        o.speculative = 0;
        let mut engine = Engine::new(o).unwrap();
        assert_eq!(engine.rt.backend_name(), "native");
        engine.submit(Request::new(
            99,
            prompt(5, 12),
            GenParams { max_new_tokens: 6, eos: None, ..Default::default() },
        ));
        let results = engine.run_until_idle().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].tokens.len(), 6);
        assert_eq!(results[0].finish, FinishReason::MaxTokens);
        let vocab = engine.info().vocab as i32;
        assert!(results[0]
            .tokens
            .iter()
            .all(|&t| (0..vocab).contains(&t)));
        assert!(engine.metrics.decode_steps >= 5, "decode ran");
        assert!(engine.metrics.prefill_steps >= 1, "prefill ran");
    });
}

#[test]
fn greedy_generation_is_deterministic() {
    with_engine(|engine| {
    let mut outs = Vec::new();
    for round in 0..2 {
        engine.submit(Request::new(
            10 + round,
            prompt(7, 16),
            GenParams { max_new_tokens: 6, eos: None, ..Default::default() },
        ));
        let r = engine.run_until_idle().unwrap();
        outs.push(r[0].tokens.clone());
    }
    assert_eq!(outs[0], outs[1], "greedy decode must be reproducible");
    });
}

#[test]
fn continuous_batching_shares_decode_steps() {
    with_engine(|engine| {
    let n = 4; // == decode bucket
    for i in 0..n {
        engine.submit(Request::new(
            i,
            prompt(i as i32, 10),
            GenParams { max_new_tokens: 8, eos: None, ..Default::default() },
        ));
    }
    let results = engine.run_until_idle().unwrap();
    assert_eq!(results.len(), n as usize);
    // 4 sequences x 8 tokens; the first token comes from prefill, so
    // decode steps must be ~7, NOT ~28 — that's continuous batching.
    assert!(
        engine.metrics.decode_steps <= 9,
        "decode steps {} should be shared across the batch",
        engine.metrics.decode_steps
    );
    });
}

#[test]
fn more_requests_than_slots_all_complete() {
    with_engine(|engine| {
    for i in 0..7 {
        assert!(engine.submit(Request::new(
            i,
            prompt(i as i32 + 3, 8),
            GenParams { max_new_tokens: 4, eos: None, ..Default::default() },
        )));
    }
    let results = engine.run_until_idle().unwrap();
    assert_eq!(results.len(), 7);
    assert!(results
        .iter()
        .all(|r| r.finish == FinishReason::MaxTokens));
    });
}

#[test]
fn oversize_prompt_is_rejected_cleanly() {
    with_engine(|engine| {
    engine.submit(Request::new(1, prompt(0, 1000), GenParams::default()));
    engine.submit(Request::new(
        2,
        prompt(0, 8),
        GenParams { max_new_tokens: 2, eos: None, ..Default::default() },
    ));
    let results = engine.run_until_idle().unwrap();
    assert_eq!(results.len(), 2);
    let rejected = results.iter().find(|r| r.id == 1).unwrap();
    assert_eq!(rejected.finish, FinishReason::Rejected);
    let ok = results.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(ok.finish, FinishReason::MaxTokens);
    });
}

#[test]
fn queue_backpressure_sheds() {
    with_engine(|engine| {
    let mut accepted = 0;
    for i in 0..20 {
        if engine.submit(Request::new(i, prompt(1, 8), GenParams::default()))
        {
            accepted += 1;
        }
    }
    assert_eq!(accepted, 8, "max_queue=8 must shed the rest");
    // drain so later tests see an empty queue
    let _ = engine.run_until_idle().unwrap();
    });
}

#[test]
fn service_handles_concurrent_callers() {
    with_engine(|_shared| {
    let svc = EngineService::spawn(opts("fp")).unwrap();
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let h = svc.handle.clone();
            std::thread::spawn(move || {
                h.generate(
                    prompt(i, 10),
                    GenParams {
                        max_new_tokens: 4,
                        eos: None,
                        ..Default::default()
                    },
                )
                .unwrap()
            })
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        assert_eq!(r.tokens.len(), 4);
    }
    let stats = svc.handle.stats().unwrap();
    assert!(stats.contains("completed=6"), "stats: {stats}");
    svc.shutdown();
    });
}

#[test]
fn staged_and_unstaged_engines_produce_identical_streams() {
    // full engine run (prefill + >=8 decode steps) with prepare-once
    // weight staging on vs the ODYSSEY_NO_STAGING escape-hatch path:
    // the token streams must match exactly, and the staging-hit
    // counters must show the staged handles were REUSED — zero weight
    // re-materializations after engine construction.
    with_engine(|_shared| {
        let run = |staging: bool| {
            let mut o = opts("w4a8_fast");
            o.staging = staging; // what ODYSSEY_NO_STAGING=1 flips off
            o.kv_quant = KvDtype::F32; // exactness vs unstaged-contiguous
            // the staged-exec arithmetic below counts one staged exec
            // per decode token; speculation would fold several tokens
            // into one verify pass (its own coverage lives in the
            // speculative tests)
            o.speculative = 0;
            let mut engine = Engine::new(o).unwrap();
            for i in 0..3u64 {
                engine.submit(Request::new(
                    i,
                    prompt(i as i32 * 5 + 2, 12),
                    GenParams {
                        max_new_tokens: 10,
                        eos: None,
                        ..Default::default()
                    },
                ));
            }
            let mut results = engine.run_until_idle().unwrap();
            results.sort_by_key(|r| r.id);
            let tokens: Vec<Vec<i32>> =
                results.into_iter().map(|r| r.tokens).collect();
            (tokens, engine.staging_stats(), engine.metrics.decode_steps)
        };

        let (staged_tokens, s_stats, decode_steps) = run(true);
        let (unstaged_tokens, u_stats, _) = run(false);

        // bit-identical serving: same logits -> same sampled streams
        assert_eq!(staged_tokens, unstaged_tokens);
        assert_eq!(staged_tokens.len(), 3);
        assert!(staged_tokens.iter().all(|t| t.len() == 10));

        // staged engine: weights materialized exactly ONCE — the decode
        // graph staged them and the prefill graph shares the handles
        // (stage_shared) — then every step reused them
        assert!(decode_steps >= 8, "want >=8 decode steps, got {decode_steps}");
        assert_eq!(
            s_stats.stage_calls, 1,
            "one weight materialization shared by both serving graphs"
        );
        assert!(
            s_stats.staged_execs >= 1 + decode_steps,
            "every prefill/decode step must hit the staged handles \
             (staged_execs={}, decode_steps={decode_steps})",
            s_stats.staged_execs
        );
        assert_eq!(
            s_stats.unstaged_execs, 0,
            "staged engine must never take the legacy execute path"
        );
        assert_eq!(
            s_stats.weight_bytes_rematerialized, 0,
            "decode steps must not copy weight payloads"
        );
        assert!(s_stats.weight_bytes_staged > 0);

        // escape hatch: no staging, every step re-materializes
        assert_eq!(u_stats.stage_calls, 0);
        assert_eq!(u_stats.staged_execs, 0);
        assert!(u_stats.unstaged_execs >= 1 + decode_steps);
        assert!(u_stats.weight_bytes_rematerialized > 0);
    });
}

#[test]
fn paged_and_contiguous_engines_produce_identical_streams() {
    // full engine run on the paged KV pool vs the ODYSSEY_NO_PAGING
    // contiguous escape hatch: token streams must match exactly, every
    // decode step must go through the block tables, and the paged path
    // must stop hauling full caches across the execution boundary.
    with_engine(|_shared| {
        let run = |paged: bool| {
            let mut o = opts("w4a8_fast");
            o.paged = paged;
            o.staging = true; // paging rides on staged weights
            o.kv_quant = KvDtype::F32; // exactness vs contiguous
            // paged_decode_steps == decode_steps below assumes the
            // one-token decode path (speculative verify goes through
            // the prefill window instead)
            o.speculative = 0;
            let mut engine = Engine::new(o).unwrap();
            assert_eq!(engine.paging_active(), paged);
            for i in 0..3u64 {
                engine.submit(Request::new(
                    i,
                    prompt(i as i32 * 5 + 2, 12),
                    GenParams {
                        max_new_tokens: 10,
                        eos: None,
                        ..Default::default()
                    },
                ));
            }
            let mut results = engine.run_until_idle().unwrap();
            results.sort_by_key(|r| r.id);
            let tokens: Vec<Vec<i32>> =
                results.into_iter().map(|r| r.tokens).collect();
            let blocks_left = engine.kv_blocks_in_use();
            (
                tokens,
                engine.staging_stats(),
                engine.metrics.decode_steps,
                blocks_left,
            )
        };

        let (paged_tokens, p_stats, p_decode, blocks_left) = run(true);
        let (contig_tokens, c_stats, _, _) = run(false);

        assert_eq!(
            paged_tokens, contig_tokens,
            "paged serving must be bit-identical to contiguous"
        );
        assert_eq!(paged_tokens.len(), 3);
        assert!(paged_tokens.iter().all(|t| t.len() == 10));

        assert!(p_decode >= 8, "want >=8 decode steps, got {p_decode}");
        assert_eq!(
            p_stats.paged_decode_steps, p_decode,
            "every decode step must run through the block tables"
        );
        assert_eq!(c_stats.paged_decode_steps, 0);
        // the point of paging: decode stops moving O(max_seq) caches
        assert!(p_stats.kv_bytes_moved > 0);
        assert!(
            p_stats.kv_bytes_moved * 10 < c_stats.kv_bytes_moved,
            "paged path moved {} KV bytes, contiguous {}",
            p_stats.kv_bytes_moved,
            c_stats.kv_bytes_moved
        );
        assert_eq!(blocks_left, 0, "drained engine must hold no blocks");
    });
}

#[test]
fn paged_engine_preempts_and_completes_under_tiny_pool() {
    // M=16 requests with mixed prompt/output lengths through 4 decode
    // slots over a pool deliberately too small for four full-length
    // sequences: every request must still complete (preempted ones are
    // re-prefilled deterministically), at least one preemption must
    // fire, and the admitted/preempted/rejected/blocks_in_use counters
    // must reconcile at the end.
    with_engine(|_shared| {
        let submit_all = |engine: &mut Engine| {
            for i in 0..16u64 {
                let plen = 6 + (i as usize % 5);
                let gen = 8 + (i as usize % 7);
                engine.submit(Request::new(
                    i,
                    prompt(i as i32 + 2, plen),
                    GenParams {
                        max_new_tokens: gen,
                        eos: None,
                        ..Default::default()
                    },
                ));
            }
        };
        // 12 blocks x 4 positions = 48 KV positions shared by 4 slots;
        // sequences need up to ceil((10 + 14 - 1) / 4) = 6 blocks each,
        // so a full decode batch MUST run the pool dry.
        let mut o = opts("fp");
        o.paged = true;
        o.staging = true; // paging rides on staged weights
        o.kv_quant = KvDtype::F32; // exactness vs contiguous
        o.kv_block_size = 4;
        o.kv_blocks = Some(12);
        o.max_queue = 32;
        let mut engine = Engine::new(o).unwrap();
        submit_all(&mut engine);
        let mut paged_res = engine.run_until_idle().unwrap();
        paged_res.sort_by_key(|r| r.id);

        assert_eq!(paged_res.len(), 16, "every request completes");
        for r in &paged_res {
            assert_eq!(r.finish, FinishReason::MaxTokens);
            assert_eq!(
                r.tokens.len(),
                8 + (r.id as usize % 7),
                "request {} got a truncated stream",
                r.id
            );
        }
        let m = &engine.metrics;
        assert!(
            m.preempted >= 1,
            "a 12-block pool must force at least one preemption"
        );
        assert_eq!(m.rejected, 0);
        assert_eq!(m.completed, 16);
        assert_eq!(
            m.admitted,
            m.completed + m.preempted,
            "every admission either completed or was preempted"
        );
        // donated prompt prefixes stay parked in the prefix index at
        // drain; beyond those, nothing may be held
        assert_eq!(
            engine.kv_blocks_in_use(),
            engine.kv_prefix_index_blocks(),
            "drained engine may hold index blocks only"
        );
        engine.flush_prefix_cache();
        assert_eq!(
            engine.kv_blocks_in_use(),
            0,
            "all blocks recycled after the drain"
        );
        assert_eq!(engine.kv_utilization(), (0, 0));

        // determinism across preemption: the contiguous engine (which
        // can never preempt) must produce the exact same streams
        let mut o = opts("fp");
        o.paged = false;
        o.max_queue = 32;
        let mut engine = Engine::new(o).unwrap();
        submit_all(&mut engine);
        let mut contig_res = engine.run_until_idle().unwrap();
        contig_res.sort_by_key(|r| r.id);
        let pt: Vec<&Vec<i32>> =
            paged_res.iter().map(|r| &r.tokens).collect();
        let ct: Vec<&Vec<i32>> =
            contig_res.iter().map(|r| &r.tokens).collect();
        assert_eq!(
            pt, ct,
            "preemption + re-prefill must reproduce identical streams"
        );
    });
}

#[test]
fn prefix_cache_engine_bit_identical_with_fewer_blocks() {
    // the PR 4 acceptance run: 8 requests sharing one long prompt
    // (prefill bucket of 1, so request 0 prefills cold and donates;
    // requests 1..8 hit the index).  With the cache on, token streams
    // must be bit-identical to ODYSSEY_NO_PREFIX_CACHE=1, while
    // allocating strictly fewer KV blocks and skipping >= 50% of the
    // batch's prefill tokens; at drain the only blocks still held are
    // the index's, and flushing it releases every one.
    with_engine(|_shared| {
        let shared_prompt = prompt(11, 16); // 4 full 4-token blocks
        let run = |prefix: bool| {
            let mut o = opts("fp");
            o.paged = true; // explicit: survives the NO_PAGING CI leg
            o.staging = true;
            o.prefix_cache = prefix;
            o.kv_quant = KvDtype::F32; // exactness across schedules
            o.prefill_batch = 1;
            o.kv_block_size = 4;
            o.kv_blocks = Some(28);
            o.max_queue = 16;
            let mut engine = Engine::new(o).unwrap();
            assert_eq!(engine.prefix_cache_active(), prefix);
            for i in 0..8u64 {
                engine.submit(Request::new(
                    i,
                    shared_prompt.clone(),
                    GenParams {
                        max_new_tokens: 6,
                        eos: None,
                        ..Default::default()
                    },
                ));
            }
            let mut results = engine.run_until_idle().unwrap();
            results.sort_by_key(|r| r.id);
            let tokens: Vec<Vec<i32>> =
                results.into_iter().map(|r| r.tokens).collect();
            (tokens, engine)
        };

        let (on_tokens, mut on) = run(true);
        let (off_tokens, off) = run(false);

        assert_eq!(
            on_tokens, off_tokens,
            "prefix-cache serving must be bit-identical to cache-off"
        );
        assert_eq!(on_tokens.len(), 8);
        assert!(on_tokens.iter().all(|t| t.len() == 6));

        // no preemption at this pool size: the counters reconcile
        // exactly against the prompt lengths
        let m = &on.metrics;
        assert_eq!(m.preempted, 0, "pool sized to avoid preemption");
        assert_eq!(m.prefix_hits, 7, "requests 1..8 hit");
        assert_eq!(
            m.prefill_tokens_skipped,
            7 * 15,
            "each full hit skips prompt_len - 1 positions"
        );
        assert_eq!(m.prefill_tokens, 8 * 16);
        assert!(
            m.prefill_tokens_skipped * 2 >= m.prefill_tokens,
            ">= 50% of the repeated-prompt batch's prefill skipped"
        );
        assert!(m.cow_forks >= 7, "every full hit forks the tail");
        assert!(m.shared_blocks >= 2, "prefix blocks were shared");
        let off_m = &off.metrics;
        assert_eq!(off_m.prefix_hits, 0);
        assert_eq!(off_m.prefill_tokens_skipped, 0);
        assert!(
            m.kv_blocks_allocated < off_m.kv_blocks_allocated,
            "cache on allocated {} blocks, cache off {} — sharing \
             must allocate strictly fewer",
            m.kv_blocks_allocated,
            off_m.kv_blocks_allocated
        );

        // every prefill ran through the paged/partial entry point
        let stats = on.staging_stats();
        assert_eq!(
            stats.paged_prefill_steps,
            on.metrics.prefill_steps
        );

        // drain accounting: only the index still holds blocks; the
        // flush releases every one (0 leaked)
        assert_eq!(
            on.kv_blocks_in_use(),
            on.kv_prefix_index_blocks(),
            "drained engine may hold index blocks only"
        );
        on.flush_prefix_cache();
        assert_eq!(on.kv_blocks_in_use(), 0, "0 blocks leaked");
        assert_eq!(off.kv_blocks_in_use(), 0);
    });
}

#[test]
fn prefix_cache_survives_preemption_of_sharers() {
    // shared-prefix requests over a pool too small for four full
    // sequences: preemption must fire, evicted sharers must release
    // only their private tails (the index and live sharers keep the
    // prefix blocks), and the streams must STILL be bit-identical to
    // the cache-off run on the same tiny pool.
    with_engine(|_shared| {
        let shared_prompt = prompt(23, 16);
        let run = |prefix: bool| {
            let mut o = opts("fp");
            o.paged = true;
            o.staging = true;
            o.prefix_cache = prefix;
            o.kv_quant = KvDtype::F32; // exactness across schedules
            o.prefill_batch = 1;
            o.kv_block_size = 4;
            o.kv_blocks = Some(12);
            o.max_queue = 16;
            let mut engine = Engine::new(o).unwrap();
            for i in 0..8u64 {
                engine.submit(Request::new(
                    i,
                    shared_prompt.clone(),
                    GenParams {
                        max_new_tokens: 6,
                        eos: None,
                        ..Default::default()
                    },
                ));
            }
            let mut results = engine.run_until_idle().unwrap();
            results.sort_by_key(|r| r.id);
            let tokens: Vec<Vec<i32>> =
                results.into_iter().map(|r| r.tokens).collect();
            (tokens, engine)
        };

        let (on_tokens, mut on) = run(true);
        let (off_tokens, _off) = run(false);

        assert_eq!(
            on_tokens, off_tokens,
            "preemption + re-prefill over shared prefixes must \
             reproduce identical streams"
        );
        assert_eq!(on_tokens.len(), 8, "every request completes");
        assert!(on_tokens.iter().all(|t| t.len() == 6));

        let m = &on.metrics;
        assert!(
            m.preempted >= 1,
            "a 12-block pool must force at least one preemption"
        );
        assert_eq!(m.rejected, 0);
        assert_eq!(m.completed, 8);
        assert_eq!(
            m.admitted,
            m.completed + m.preempted,
            "every admission either completed or was preempted"
        );
        assert!(m.prefix_hits >= 7, "sharers kept hitting the index");

        // eviction released only private tails: the index blocks all
        // survived to the drain, and nothing beyond them is held
        assert_eq!(
            on.kv_blocks_in_use(),
            on.kv_prefix_index_blocks()
        );
        on.flush_prefix_cache();
        assert_eq!(on.kv_blocks_in_use(), 0, "0 blocks leaked");
    });
}

#[test]
fn chunked_prefill_removes_decode_stalls_and_keeps_streams() {
    // the tentpole acceptance run: three short requests decode while a
    // LONG prompt arrives.  The legacy two-phase loop stalls every
    // active decode behind the whole-prompt prefill; the fused
    // scheduler advances the prompt chunk-by-chunk with zero decode
    // stalls, and the token streams stay bit-identical.
    with_engine(|_shared| {
        let long_prompt = prompt(31, 96); // 24 KV blocks of 4
        let run = |chunking: bool| {
            let mut o = opts("fp");
            o.paged = true;
            o.staging = true;
            o.chunking = chunking;
            o.kv_quant = KvDtype::F32; // exactness across chunk schedules
            // the ITL p50 == 1.0 steady-state assert below counts one
            // token per engine step; a verify pass emitting a batch of
            // tokens in one step would skew it by design
            o.speculative = 0;
            o.step_token_budget = 16;
            o.kv_block_size = 4;
            o.max_queue = 16;
            let mut engine = Engine::new(o).unwrap();
            assert_eq!(engine.chunking_active(), chunking);
            for i in 0..3u64 {
                engine.submit(Request::new(
                    i,
                    prompt(i as i32 + 1, 8),
                    GenParams {
                        max_new_tokens: 30,
                        eos: None,
                        ..Default::default()
                    },
                ));
            }
            // get the short requests prefilled and decoding first
            engine.step().unwrap();
            engine.step().unwrap();
            assert!(engine.metrics.decode_tokens > 0, "decodes active");
            engine.submit(Request::new(
                10,
                long_prompt.clone(),
                GenParams {
                    max_new_tokens: 4,
                    eos: None,
                    ..Default::default()
                },
            ));
            let mut results = engine.run_until_idle().unwrap();
            results.sort_by_key(|r| r.id);
            (results, engine)
        };

        let (on_res, mut on) = run(true);
        let (off_res, off) = run(false);

        let on_tokens: Vec<&Vec<i32>> =
            on_res.iter().map(|r| &r.tokens).collect();
        let off_tokens: Vec<&Vec<i32>> =
            off_res.iter().map(|r| &r.tokens).collect();
        assert_eq!(
            on_tokens, off_tokens,
            "chunked serving must be bit-identical to chunking-off"
        );
        assert_eq!(on_res.len(), 4);

        // the fused scheduler never withholds a decode token; the
        // legacy loop stalls every active behind the long prefill
        let m_on = &on.metrics;
        let m_off = &off.metrics;
        assert_eq!(
            m_on.max_decode_stall_steps, 0,
            "fused scheduler must decode every iteration"
        );
        assert!(
            m_off.max_decode_stall_steps >= 1,
            "legacy loop must stall actives behind the long prefill"
        );
        assert!(
            m_on.max_decode_stall_steps < m_off.max_decode_stall_steps,
            "chunking must strictly improve the worst decode stall"
        );
        // no decode slot waits more than ceil(prompt/chunk) steps; the
        // long prompt's first token lands within its chunk count plus
        // scheduling slack.  With budget 16 and 3 actives the chunk is
        // >= 12 positions, so 96 tokens need <= 8 chunks.
        let long = on_res.iter().find(|r| r.id == 10).unwrap();
        let chunks = 96usize.div_ceil(12) as u64;
        assert!(
            long.ttft_steps <= chunks + 4,
            "long-prompt TTFT {} steps exceeds {} chunks + slack",
            long.ttft_steps,
            chunks
        );
        assert!(m_on.engine_steps > 0 && m_on.decode_steps > 0);
        // steady-state ITL of the fused path is one token per step
        assert_eq!(on.metrics.itl_steps_pcts().0, 1.0, "itl p50");
    });
}

#[test]
fn escape_hatch_matrix_produces_identical_streams() {
    // every combination of ODYSSEY_NO_PAGING x ODYSSEY_NO_PREFIX_CACHE
    // x ODYSSEY_NO_CHUNKING x ODYSSEY_KV_QUANT (exercised through
    // their EngineOptions equivalents) — fp-KV combos must produce
    // bit-identical token streams; int8-KV combos are LOSSY by
    // contract (in-window prefill reads stay f32 while history reads
    // dequantize, so different chunk schedules legitimately see
    // different rounding) and are flagged on divergence, not failed.
    // Mixed workload: two distinct prompts, one repeated prompt
    // (prefix-hit shape), one long prompt (multi-chunk shape).
    with_engine(|_shared| {
        let shared_prompt = prompt(41, 16);
        let run = |paged: bool,
                   prefix: bool,
                   chunking: bool,
                   kv_quant: KvDtype,
                   spec: usize| {
            let mut o = opts("fp");
            o.paged = paged;
            o.staging = true;
            o.prefix_cache = prefix;
            o.chunking = chunking;
            o.kv_quant = kv_quant;
            o.speculative = spec;
            o.step_token_budget = 12; // small: forces real chunking
            o.kv_block_size = 4;
            o.max_queue = 16;
            let mut engine = Engine::new(o).unwrap();
            for (i, p) in [
                prompt(3, 9),
                shared_prompt.clone(),
                prompt(17, 40),
                shared_prompt.clone(),
                prompt(29, 12),
            ]
            .into_iter()
            .enumerate()
            {
                engine.submit(Request::new(
                    i as u64,
                    p,
                    GenParams {
                        max_new_tokens: 5,
                        eos: None,
                        ..Default::default()
                    },
                ));
            }
            let mut results = engine.run_until_idle().unwrap();
            results.sort_by_key(|r| r.id);
            results
                .into_iter()
                .map(|r| r.tokens)
                .collect::<Vec<_>>()
        };

        let reference = run(false, false, false, KvDtype::F32, 0);
        assert_eq!(reference.len(), 5);
        assert!(reference.iter().all(|t| t.len() == 5));
        // speculative axis: k=3 on fp KV must stay bit-identical too
        // (draft proposals only ever get emitted after the target
        // verifies them; paging-off combos silently fall back to
        // plain decode, which is the same stream by construction)
        for paged in [false, true] {
            for prefix in [false, true] {
                for chunking in [false, true] {
                    for spec in [0usize, 3] {
                        let got = run(
                            paged, prefix, chunking, KvDtype::F32,
                            spec,
                        );
                        assert_eq!(
                            got, reference,
                            "paging={paged} prefix={prefix} \
                             chunking={chunking} spec={spec} diverged \
                             from the all-hatches-off baseline"
                        );
                    }
                }
            }
        }
        // int8-KV axis (paged only — the contiguous path has no
        // pool; spec pinned off — int8 history reads dequantize, so
        // the verify window may legitimately round differently):
        // every combo must COMPLETE with full-length streams;
        // divergence from the fp baseline is expected quantization
        // behavior, logged so schedule-sensitivity stays visible
        for prefix in [false, true] {
            for chunking in [false, true] {
                let got =
                    run(true, prefix, chunking, KvDtype::Int8, 0);
                assert_eq!(got.len(), 5);
                assert!(
                    got.iter().all(|t| t.len() == 5),
                    "int8 prefix={prefix} chunking={chunking}: \
                     stream truncated"
                );
                if got != reference {
                    eprintln!(
                        "note: int8 KV (prefix={prefix} \
                         chunking={chunking}) diverged from fp \
                         streams — lossy path, allowed"
                    );
                }
            }
        }
    });
}

#[test]
fn oversize_prompts_reject_up_front_on_both_kv_paths() {
    // bugfix satellite: a prompt the decode path can never extend
    // (len >= max_seq) must bounce with FinishReason::Rejected at
    // admission on BOTH KV paths — it used to be caught only deep in
    // the runtime on the contiguous path
    with_engine(|_shared| {
        for paged in [true, false] {
            let mut o = opts("fp");
            o.paged = paged;
            o.max_queue = 16;
            let mut engine = Engine::new(o).unwrap();
            let max_seq = engine.info().max_seq;
            engine.submit(Request::new(
                1,
                prompt(0, max_seq),
                GenParams::default(),
            ));
            engine.submit(Request::new(
                2,
                prompt(0, 8),
                GenParams {
                    max_new_tokens: 2,
                    eos: None,
                    ..Default::default()
                },
            ));
            let results = engine.run_until_idle().unwrap();
            let rejected =
                results.iter().find(|r| r.id == 1).unwrap();
            assert_eq!(
                rejected.finish,
                FinishReason::Rejected,
                "paged={paged}: oversize prompt must reject cleanly"
            );
            assert!(rejected.tokens.is_empty());
            let ok = results.iter().find(|r| r.id == 2).unwrap();
            assert_eq!(ok.tokens.len(), 2, "paged={paged}");
        }
    });
}

#[test]
fn max_prompt_cap_validated_at_construction() {
    with_engine(|_shared| {
        // a cap the prefill graph cannot serve is a construction error
        let mut o = opts("fp");
        o.max_prompt = Some(4096);
        assert!(
            Engine::new(o).is_err(),
            "max_prompt beyond the seq bucket must fail construction"
        );
        let mut o = opts("fp");
        o.max_prompt = Some(0);
        assert!(Engine::new(o).is_err(), "zero cap must fail");
        let mut o = opts("fp");
        o.step_token_budget = 0;
        assert!(Engine::new(o).is_err(), "zero budget must fail");
        // a valid tighter cap admits under it and rejects over it
        let mut o = opts("fp");
        o.max_prompt = Some(10);
        let mut engine = Engine::new(o).unwrap();
        engine.submit(Request::new(
            1,
            prompt(0, 12),
            GenParams::default(),
        ));
        engine.submit(Request::new(
            2,
            prompt(0, 10),
            GenParams {
                max_new_tokens: 2,
                eos: None,
                ..Default::default()
            },
        ));
        let results = engine.run_until_idle().unwrap();
        assert_eq!(
            results.iter().find(|r| r.id == 1).unwrap().finish,
            FinishReason::Rejected
        );
        assert_eq!(
            results.iter().find(|r| r.id == 2).unwrap().tokens.len(),
            2
        );
    });
}

#[test]
fn no_chunking_env_var_flips_the_default() {
    // same serialization rationale as the staging/paging twins below
    with_engine(|_shared| {
        let saved = std::env::var("ODYSSEY_NO_CHUNKING").ok();
        std::env::remove_var("ODYSSEY_NO_CHUNKING");
        let on_by_default = odyssey::runtime::chunking_enabled_from_env();
        std::env::set_var("ODYSSEY_NO_CHUNKING", "1");
        let off = odyssey::runtime::chunking_enabled_from_env();
        let opts_off = EngineOptions::default().chunking;
        match saved {
            Some(v) => std::env::set_var("ODYSSEY_NO_CHUNKING", v),
            None => std::env::remove_var("ODYSSEY_NO_CHUNKING"),
        }
        assert!(on_by_default, "chunking must default on");
        assert!(!off, "ODYSSEY_NO_CHUNKING=1 must disable it");
        assert!(!opts_off, "EngineOptions::default must honor the env");

        // the step-token-budget env override, same serialization
        let saved = std::env::var("ODYSSEY_STEP_TOKEN_BUDGET").ok();
        std::env::set_var("ODYSSEY_STEP_TOKEN_BUDGET", "24");
        let opts_budget = EngineOptions::default().step_token_budget;
        std::env::set_var("ODYSSEY_STEP_TOKEN_BUDGET", "0");
        let zero_ignored =
            odyssey::runtime::step_token_budget_from_env();
        match saved {
            Some(v) => {
                std::env::set_var("ODYSSEY_STEP_TOKEN_BUDGET", v)
            }
            None => {
                std::env::remove_var("ODYSSEY_STEP_TOKEN_BUDGET")
            }
        }
        assert_eq!(opts_budget, 24, "env budget must flow to options");
        assert_eq!(zero_ignored, None, "a zero budget is ignored");
    });
}

#[test]
fn kv_quant_env_var_flips_the_default() {
    // same serialization rationale as the staging/paging twins below
    with_engine(|_shared| {
        let saved = std::env::var("ODYSSEY_KV_QUANT").ok();
        std::env::remove_var("ODYSSEY_KV_QUANT");
        let default = EngineOptions::default().kv_quant;
        std::env::set_var("ODYSSEY_KV_QUANT", "int8");
        let opted_in = EngineOptions::default().kv_quant;
        std::env::set_var("ODYSSEY_KV_QUANT", "bf13");
        let invalid = odyssey::runtime::kv_quant_from_env();
        match saved {
            Some(v) => std::env::set_var("ODYSSEY_KV_QUANT", v),
            None => std::env::remove_var("ODYSSEY_KV_QUANT"),
        }
        assert_eq!(
            default,
            KvDtype::F32,
            "fp32 must stay the out-of-the-box default"
        );
        assert_eq!(
            opted_in,
            KvDtype::Int8,
            "ODYSSEY_KV_QUANT=int8 must flow into EngineOptions"
        );
        assert_eq!(
            invalid,
            KvDtype::F32,
            "an unknown dtype must fall back to fp32, not panic"
        );
    });
}

#[test]
fn int8_kv_engine_completes_and_repeats_streams() {
    // The int8 pool is LOSSY, so no fp comparison here — the
    // engine-level contract is (a) every request runs to completion
    // through quantized paged attention with sane counters, and
    // (b) the path is deterministic: two identical runs (same
    // schedule) must produce byte-identical streams, because the
    // per-(block, head) scales are a pure function of write history.
    with_engine(|_shared| {
        let run = || {
            let mut o = opts("fp");
            o.paged = true;
            o.staging = true;
            o.kv_quant = KvDtype::Int8;
            o.kv_block_size = 4;
            let mut engine = Engine::new(o).unwrap();
            for i in 0..4u64 {
                engine.submit(Request::new(
                    i,
                    prompt(i as i32 + 11, 7 + i as usize),
                    GenParams {
                        max_new_tokens: 6,
                        eos: None,
                        ..Default::default()
                    },
                ));
            }
            let mut results = engine.run_until_idle().unwrap();
            results.sort_by_key(|r| r.id);
            assert_eq!(results.len(), 4, "every request completes");
            for r in &results {
                assert_eq!(r.finish, FinishReason::MaxTokens);
                assert_eq!(
                    r.tokens.len(),
                    6,
                    "request {} got a truncated stream",
                    r.id
                );
            }
            let m = &engine.metrics;
            assert_eq!(m.completed, 4);
            assert_eq!(m.rejected, 0);
            assert!(
                m.kv_blocks_allocated > 0,
                "int8 requests must still allocate pool blocks"
            );
            results
                .into_iter()
                .map(|r| r.tokens)
                .collect::<Vec<_>>()
        };
        let first = run();
        let second = run();
        assert_eq!(
            first, second,
            "int8 KV must be deterministic across identical runs"
        );
    });
}

#[test]
fn no_prefix_cache_env_var_flips_the_default() {
    // same serialization rationale as the staging/paging twins below
    with_engine(|_shared| {
        let saved = std::env::var("ODYSSEY_NO_PREFIX_CACHE").ok();
        std::env::remove_var("ODYSSEY_NO_PREFIX_CACHE");
        let on_by_default =
            odyssey::runtime::prefix_cache_enabled_from_env();
        std::env::set_var("ODYSSEY_NO_PREFIX_CACHE", "1");
        let off = odyssey::runtime::prefix_cache_enabled_from_env();
        let opts_off = EngineOptions::default().prefix_cache;
        match saved {
            Some(v) => std::env::set_var("ODYSSEY_NO_PREFIX_CACHE", v),
            None => std::env::remove_var("ODYSSEY_NO_PREFIX_CACHE"),
        }
        assert!(on_by_default, "prefix cache must default on");
        assert!(!off, "ODYSSEY_NO_PREFIX_CACHE=1 must disable it");
        assert!(!opts_off, "EngineOptions::default must honor the env");
    });
}

#[test]
fn no_paging_env_var_flips_the_default() {
    // same serialization rationale as the staging twin below
    with_engine(|_shared| {
        let saved = std::env::var("ODYSSEY_NO_PAGING").ok();
        std::env::remove_var("ODYSSEY_NO_PAGING");
        let on_by_default = odyssey::runtime::paging_enabled_from_env();
        std::env::set_var("ODYSSEY_NO_PAGING", "1");
        let off = odyssey::runtime::paging_enabled_from_env();
        let opts_off = EngineOptions::default().paged;
        match saved {
            Some(v) => std::env::set_var("ODYSSEY_NO_PAGING", v),
            None => std::env::remove_var("ODYSSEY_NO_PAGING"),
        }
        assert!(on_by_default, "paging must default on when env unset");
        assert!(!off, "ODYSSEY_NO_PAGING=1 must disable paging");
        assert!(!opts_off, "EngineOptions::default must honor the env");
    });
}

#[test]
fn no_staging_env_var_flips_the_default() {
    // serialized via with_engine so the env flip cannot race another
    // engine construction in this binary; the caller's own value of the
    // variable is snapshotted and restored so running the whole suite
    // under ODYSSEY_NO_STAGING=1 stays green
    with_engine(|_shared| {
        let saved = std::env::var("ODYSSEY_NO_STAGING").ok();
        std::env::remove_var("ODYSSEY_NO_STAGING");
        let on_by_default = odyssey::runtime::staging_enabled_from_env();
        std::env::set_var("ODYSSEY_NO_STAGING", "1");
        let off = odyssey::runtime::staging_enabled_from_env();
        let opts_off = EngineOptions::default().staging;
        match saved {
            Some(v) => std::env::set_var("ODYSSEY_NO_STAGING", v),
            None => std::env::remove_var("ODYSSEY_NO_STAGING"),
        }
        assert!(on_by_default, "staging must default on when env unset");
        assert!(!off, "ODYSSEY_NO_STAGING=1 must disable staging");
        assert!(!opts_off, "EngineOptions::default must honor the env");
    });
}

#[test]
fn spec_k_env_var_opts_into_speculation() {
    // same serialization rationale as the staging/paging twins above
    with_engine(|_shared| {
        let saved = std::env::var("ODYSSEY_SPEC_K").ok();
        std::env::remove_var("ODYSSEY_SPEC_K");
        let off_by_default = EngineOptions::default().speculative;
        std::env::set_var("ODYSSEY_SPEC_K", "4");
        let opted_in = EngineOptions::default().speculative;
        std::env::set_var("ODYSSEY_SPEC_K", "0");
        let zero = odyssey::runtime::spec_k_from_env();
        std::env::set_var("ODYSSEY_SPEC_K", "many");
        let junk = odyssey::runtime::spec_k_from_env();
        match saved {
            Some(v) => std::env::set_var("ODYSSEY_SPEC_K", v),
            None => std::env::remove_var("ODYSSEY_SPEC_K"),
        }
        assert_eq!(
            off_by_default, 0,
            "speculation must stay opt-in (default off)"
        );
        assert_eq!(
            opted_in, 4,
            "ODYSSEY_SPEC_K=4 must flow into EngineOptions"
        );
        assert_eq!(zero, None, "an explicit 0 stays off");
        assert_eq!(junk, None, "unparsable values stay off, not panic");
    });
}

#[test]
fn speculative_decoding_is_bit_identical_to_plain_greedy() {
    // The speculative contract: draft-k proposals only ever reach the
    // stream after the target verifies them in its own chunk-window
    // pass, and the first divergence is replaced by the target's own
    // token — so `--draft-k` must change THROUGHPUT SHAPE (several
    // tokens per target pass), never the tokens.  Mixed greedy
    // workload: different lengths, an eos-armed request, a
    // stop-sequence request, plus enough new tokens that rollbacks
    // and re-drafts actually happen.
    with_engine(|_shared| {
        let run = |k: usize| {
            let mut o = opts("fp");
            o.paged = true;
            o.staging = true;
            o.kv_quant = KvDtype::F32; // exactness vs plain decode
            o.speculative = k;
            o.max_queue = 16;
            let mut engine = Engine::new(o).unwrap();
            assert_eq!(engine.speculative_active(), k > 0);
            for i in 0..4u64 {
                engine.submit(Request::new(
                    i,
                    prompt(i as i32 * 3 + 2, 6 + 2 * i as usize),
                    GenParams {
                        max_new_tokens: 10 + i as usize,
                        eos: if i == 2 { Some(2) } else { None },
                        stop: if i == 3 {
                            vec![vec![7, 8]]
                        } else {
                            Vec::new()
                        },
                        ..Default::default()
                    },
                ));
            }
            let mut results = engine.run_until_idle().unwrap();
            results.sort_by_key(|r| r.id);
            let streams: Vec<(Vec<i32>, FinishReason)> = results
                .into_iter()
                .map(|r| (r.tokens, r.finish))
                .collect();
            (streams, engine)
        };
        let (spec_streams, spec) = run(4);
        let (plain_streams, plain) = run(0);
        assert_eq!(
            spec_streams, plain_streams,
            "speculative greedy must be bit-identical to plain greedy \
             (tokens AND finish reasons)"
        );
        let m = &spec.metrics;
        assert!(m.spec_steps > 0, "verify passes must have run");
        assert!(
            m.draft_tokens_proposed >= m.spec_steps,
            "each verify pass scores at least one proposal"
        );
        assert!(
            m.spec_emitted_tokens >= m.spec_steps,
            "each verify pass emits at least the target's own token"
        );
        assert!(
            m.accepted_tokens_per_target_step() >= 1.0,
            "emitted/verify-pass must be at least 1.0, got {}",
            m.accepted_tokens_per_target_step()
        );
        assert_eq!(
            plain.metrics.spec_steps, 0,
            "k=0 must never touch the speculative path"
        );
        // the block pools of both engines drained clean
        assert_eq!(spec.kv_blocks_in_use(), 0);
    });
}

#[test]
fn speculation_with_missing_draft_model_fails_construction() {
    // fault injection: requesting speculation for a model whose
    // `{model}_draft` companion is not in the manifest must fail FAST
    // at construction with an actionable error — not at the first
    // decode step.  `tiny3m_draft` is itself a model with serving
    // graphs, but `tiny3m_draft_draft` does not exist.
    with_engine(|_shared| {
        let mut o = opts("fp");
        o.model = "tiny3m_draft".into();
        o.paged = true;
        o.staging = true;
        o.speculative = 2;
        let err = match Engine::new(o) {
            Ok(_) => panic!("construction must fail without a draft"),
            Err(e) => format!("{e:#}"),
        };
        assert!(
            err.contains("tiny3m_draft_draft"),
            "error must name the missing companion: {err}"
        );
        assert!(
            err.contains("speculative"),
            "error must say speculation needs it: {err}"
        );
        // same options with speculation off must construct fine
        let mut o = opts("fp");
        o.model = "tiny3m_draft".into();
        o.paged = true;
        o.staging = true;
        o.speculative = 0;
        Engine::new(o).expect("draft model serves fine as a target");
    });
}

/// Logits at the last prompt position from the b=4 prefill graph
/// (row 0 carries the prompt; the other rows are padding).
fn last_pos_logits(engine: &mut Engine, prompt: &[i32]) -> Vec<f32> {
    let (b, s, v) = engine.prefill_dims();
    let mut tokens = vec![0i32; b * s];
    let mut lengths = vec![1i32; b];
    tokens[..prompt.len()].copy_from_slice(prompt);
    lengths[0] = prompt.len() as i32;
    let logits = engine.prefill_logits(&tokens, &lengths).unwrap();
    let off = (prompt.len() - 1) * v;
    logits[off..off + v].to_vec()
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// How many logits strictly exceed the one at `idx` (0 = argmax).
fn rank_of(xs: &[f32], idx: usize) -> usize {
    xs.iter().filter(|&&v| v > xs[idx]).count()
}

#[test]
fn variant_engines_agree_on_next_token() {
    // all bit widths serve the same model: on an in-distribution prompt
    // the FP and W8A8 engines must rank the next token (nearly) the
    // same — each one's greedy choice sits in the other's top 5.  (The
    // synthetic checkpoint is untrained, so exact argmax equality would
    // over-constrain 8-bit rounding noise on near-tied logits.)
    let p: Vec<i32> = vec![1, 3, 220, 150, 3, 80, 12];
    let fp_logits = with_engine(|engine| last_pos_logits(engine, &p));
    let w8_logits = with_engine(|_shared| {
        let mut engine = Engine::new(opts("w8a8")).unwrap();
        last_pos_logits(&mut engine, &p)
    });
    assert_eq!(fp_logits.len(), w8_logits.len());
    let fp_top = argmax(&fp_logits);
    let w8_top = argmax(&w8_logits);
    assert!(
        rank_of(&w8_logits, fp_top) < 5,
        "fp argmax {fp_top} ranks {} under w8a8",
        rank_of(&w8_logits, fp_top)
    );
    assert!(
        rank_of(&fp_logits, w8_top) < 5,
        "w8a8 argmax {w8_top} ranks {} under fp",
        rank_of(&fp_logits, w8_top)
    );
}

#[test]
fn kernel_sets_produce_identical_token_streams() {
    // the end-to-end half of the dispatch contract: a full serving run
    // (prefill + continuous-batched decode) through each kernel set
    // must emit bit-identical token streams — ODYSSEY_KERNELS (and the
    // --kernels flag feeding EngineOptions::kernels) is a pure speed
    // knob.  The choice rides EngineOptions rather than the env var so
    // parallel test binaries cannot race on process state.
    use odyssey::kernels::KernelChoice;

    with_engine(|_shared| {
        let run = |choice: KernelChoice| {
            let mut o = opts("w4a8_fast");
            o.kernels = choice;
            let mut engine = Engine::new(o).expect("engine");
            for i in 0..3u64 {
                engine.submit(Request::new(
                    i,
                    prompt(11 + i as i32, 10 + 3 * i as usize),
                    GenParams {
                        max_new_tokens: 6,
                        eos: None,
                        ..Default::default()
                    },
                ));
            }
            let mut results = engine.run_until_idle().expect("drain");
            results.sort_by_key(|r| r.id);
            results
                .into_iter()
                .map(|r| r.tokens)
                .collect::<Vec<Vec<i32>>>()
        };
        let scalar = run(KernelChoice::Scalar);
        let blocked = run(KernelChoice::Blocked);
        let parallel = run(KernelChoice::Parallel);
        assert_eq!(scalar.len(), 3);
        assert!(scalar.iter().all(|t| t.len() == 6));
        assert_eq!(
            scalar, blocked,
            "blocked kernel set changed the token streams"
        );
        assert_eq!(
            scalar, parallel,
            "parallel kernel set changed the token streams"
        );
    });
}

#[test]
fn parallel_sampling_shares_prompt_blocks_via_cow() {
    // the tentpole acceptance run: one n=4 request prefills ONCE and
    // forks into 4 siblings that retain the prompt blocks; the fork
    // must allocate strictly fewer KV blocks than 4 independent copies
    // of the same request, the shared tail must CoW-split on the first
    // diverging write, and greedy branches must stay bit-identical to
    // a plain n=1 run.
    with_engine(|_shared| {
        // 10 tokens over 4-position blocks: the tail block is half
        // full, so every sibling's first decode write hits shared
        // storage and must CoW-fork it
        let p = prompt(13, 10);
        let run = |n: usize, requests: u64| {
            let mut o = opts("fp");
            o.paged = true;
            o.staging = true;
            o.prefix_cache = false; // isolate fork sharing from the index
            o.kv_block_size = 4;
            o.max_queue = 16;
            let mut engine = Engine::new(o).unwrap();
            for i in 0..requests {
                engine.submit(Request::new(
                    i,
                    p.clone(),
                    GenParams {
                        max_new_tokens: 6,
                        eos: None,
                        n,
                        ..Default::default()
                    },
                ));
            }
            let mut results = engine.run_until_idle().unwrap();
            results.sort_by_key(|r| r.id);
            (results, engine)
        };

        let (forked, engine) = run(4, 1);
        assert_eq!(forked.len(), 1, "n=4 is ONE aggregated result");
        let res = &forked[0];
        assert_eq!(res.branches.len(), 4);
        for b in &res.branches {
            assert_eq!(b.finish, FinishReason::MaxTokens);
            assert_eq!(b.tokens.len(), 6);
        }
        // greedy ignores the per-branch rng: every sibling must decode
        // the identical stream, matching a plain n=1 request
        let (single, _) = run(1, 1);
        for b in &res.branches {
            assert_eq!(b.tokens, single[0].tokens);
        }
        assert_eq!(res.tokens, single[0].tokens, "back-compat view");

        let m = &engine.metrics;
        assert_eq!(m.forked_branches, 3, "n=4 forks three siblings");
        assert!(
            m.cow_forks >= 3,
            "each sibling's first write must CoW-split the shared \
             tail block (cow_forks={})",
            m.cow_forks
        );
        assert_eq!(m.completed, 1, "n=4 counts as ONE completion");
        assert_eq!(engine.kv_blocks_in_use(), 0, "drained: no leaks");
        let forked_blocks = m.kv_blocks_allocated;

        // baseline: 4 independent requests with the same prompt (the
        // prefix cache is off, so nothing is shared between them)
        let (indep, engine) = run(1, 4);
        assert_eq!(indep.len(), 4);
        for r in &indep {
            assert_eq!(r.tokens, single[0].tokens);
        }
        assert!(
            forked_blocks < engine.metrics.kv_blocks_allocated,
            "n=4 fork allocated {} blocks, 4 independent requests {} \
             — prompt sharing must allocate strictly fewer",
            forked_blocks,
            engine.metrics.kv_blocks_allocated
        );
    });
}

#[test]
fn contiguous_engine_forks_siblings_by_deep_copy() {
    // the ODYSSEY_NO_PAGING path serves n>1 by deep-copying the
    // prompt's KV rows instead of CoW block sharing; the sampled
    // branch streams must be bit-identical across both KV paths, and
    // distinct branch seeds must make the siblings diverge.
    with_engine(|_shared| {
        let run = |paged: bool| {
            let mut o = opts("fp");
            o.paged = paged;
            o.staging = true;
            o.kv_block_size = 4;
            o.max_queue = 16;
            let mut engine = Engine::new(o).unwrap();
            engine.submit(Request::new(
                1,
                prompt(9, 10),
                GenParams {
                    max_new_tokens: 6,
                    eos: None,
                    n: 2,
                    temperature: 0.7,
                    seed: 77,
                    ..Default::default()
                },
            ));
            engine.run_until_idle().unwrap()
        };
        let paged = run(true);
        let contig = run(false);
        assert_eq!(paged.len(), 1);
        assert_eq!(contig.len(), 1);
        assert_eq!(paged[0].branches.len(), 2);
        assert_eq!(contig[0].branches.len(), 2);
        for b in 0..2 {
            assert_eq!(
                paged[0].branches[b].tokens,
                contig[0].branches[b].tokens,
                "branch {b} diverged across KV paths"
            );
            assert_eq!(paged[0].branches[b].tokens.len(), 6);
        }
        assert_ne!(
            paged[0].branches[0].tokens, paged[0].branches[1].tokens,
            "sampled siblings draw from independent branch seeds"
        );
    });
}

#[test]
fn preempted_sampled_streams_replay_bit_identical() {
    // replayable-rng satellite: preemption re-prefills a sampled
    // (temperature > 0) request and regenerates its stream from the
    // same branch seed, so the paged tiny-pool run (which preempts)
    // must produce streams bit-identical to the contiguous engine
    // (which never preempts).
    with_engine(|_shared| {
        let submit_all = |engine: &mut Engine| {
            for i in 0..16u64 {
                let plen = 6 + (i as usize % 5);
                let gen = 8 + (i as usize % 7);
                engine.submit(Request::new(
                    i,
                    prompt(i as i32 + 2, plen),
                    GenParams {
                        max_new_tokens: gen,
                        eos: None,
                        temperature: 0.9,
                        top_k: 40,
                        top_p: 0.95,
                        seed: 1234,
                        ..Default::default()
                    },
                ));
            }
        };
        let mut o = opts("fp");
        o.paged = true;
        o.staging = true;
        o.kv_quant = KvDtype::F32; // replay exactness vs contiguous
        o.kv_block_size = 4;
        o.kv_blocks = Some(12);
        o.max_queue = 32;
        let mut engine = Engine::new(o).unwrap();
        submit_all(&mut engine);
        let mut paged_res = engine.run_until_idle().unwrap();
        paged_res.sort_by_key(|r| r.id);
        assert_eq!(paged_res.len(), 16, "every request completes");
        for r in &paged_res {
            assert_eq!(r.tokens.len(), 8 + (r.id as usize % 7));
        }
        assert!(
            engine.metrics.preempted >= 1,
            "a 12-block pool must force at least one preemption"
        );

        let mut o = opts("fp");
        o.paged = false;
        o.max_queue = 32;
        let mut engine = Engine::new(o).unwrap();
        submit_all(&mut engine);
        let mut contig_res = engine.run_until_idle().unwrap();
        contig_res.sort_by_key(|r| r.id);

        let pt: Vec<&Vec<i32>> =
            paged_res.iter().map(|r| &r.tokens).collect();
        let ct: Vec<&Vec<i32>> =
            contig_res.iter().map(|r| &r.tokens).collect();
        assert_eq!(
            pt, ct,
            "preemption + seeded-rng replay must reproduce identical \
             sampled streams"
        );
    });
}

#[test]
fn nan_logits_finish_with_error_instead_of_panicking() {
    // bugfix satellite: a NaN logits row used to panic the top-k
    // sort's partial_cmp().unwrap() (and greedy argmax silently chose
    // token 0).  The sampler now detects the poisoned row up front and
    // finishes the branch with FinishReason::Error — on BOTH the
    // greedy and the sampled path — while the engine thread survives.
    with_engine(|_shared| {
        for temperature in [0.0f32, 0.8] {
            let mut o = opts("fp");
            o.nan_logits_after = Some(3);
            // fault injection poisons the plain decode path's logits;
            // under speculation the greedy arm would never hit it
            o.speculative = 0;
            o.max_queue = 16;
            let mut engine = Engine::new(o).unwrap();
            for i in 0..3u64 {
                engine.submit(Request::new(
                    i,
                    prompt(i as i32 + 4, 8),
                    GenParams {
                        max_new_tokens: 12,
                        eos: None,
                        temperature,
                        seed: 5,
                        ..Default::default()
                    },
                ));
            }
            let results = engine.run_until_idle().unwrap();
            assert_eq!(results.len(), 3, "temperature={temperature}");
            for r in &results {
                assert_eq!(
                    r.finish,
                    FinishReason::Error,
                    "temperature={temperature}: a NaN row must error \
                     the request, not panic or emit token 0"
                );
                assert!(
                    r.tokens.len() < 12,
                    "temperature={temperature}: the stream stops at \
                     the poisoned step"
                );
            }
        }
    });
}

#[test]
fn stop_sequences_finish_with_stop() {
    with_engine(|engine| {
        engine.submit(Request::new(
            1,
            prompt(3, 12),
            GenParams {
                max_new_tokens: 8,
                eos: None,
                ..Default::default()
            },
        ));
        let r = engine.run_until_idle().unwrap();
        let toks = r[0].tokens.clone();
        assert_eq!(toks.len(), 8);
        // stop on the 3rd+4th generated tokens: the greedy replay must
        // halt right after emitting them (stop tokens stay in the
        // output, matching the streamed frames)
        engine.submit(Request::new(
            2,
            prompt(3, 12),
            GenParams {
                max_new_tokens: 8,
                eos: None,
                stop: vec![toks[2..4].to_vec()],
                ..Default::default()
            },
        ));
        let r = engine.run_until_idle().unwrap();
        assert_eq!(r[0].finish, FinishReason::Stop);
        assert_eq!(r[0].tokens, toks[..4].to_vec());
    });
}
