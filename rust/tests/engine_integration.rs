//! Engine / coordinator integration over the real artifacts: generation
//! correctness, continuous batching, determinism, shedding, and the
//! thread-safe service front door.

use std::sync::{Mutex, OnceLock};

use odyssey::coordinator::handle::EngineService;
use odyssey::coordinator::request::FinishReason;
use odyssey::coordinator::{Engine, EngineOptions, GenParams, Request};
use odyssey::quant::QuantRecipe;

/// Serialize engine construction: each PJRT client spawns a full CPU
/// thread pool, so cargo's parallel tests must not build engines
/// concurrently (Engine itself is !Send — the client uses Rc).
fn with_engine<R>(f: impl FnOnce(&mut Engine) -> R) -> R {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let _guard = LOCK.get_or_init(|| Mutex::new(())).lock().unwrap();
    let mut engine = Engine::new(opts("fp")).expect("make artifacts first");
    engine.reset_metrics();
    f(&mut engine)
}

fn opts(variant: &str) -> EngineOptions {
    EngineOptions {
        variant: variant.into(),
        // vanilla: engine tests exercise SERVING, not quantizer quality
        recipe: if variant == "w8a8" {
            QuantRecipe::smoothquant_w8()
        } else {
            QuantRecipe::vanilla_w4()
        },
        max_queue: 8,
        ..Default::default()
    }
}

fn prompt(seed: i32, len: usize) -> Vec<i32> {
    (0..len).map(|i| 3 + ((seed + i as i32 * 7) % 500)).collect()
}

#[test]
fn generates_requested_tokens() {
    with_engine(|engine| {
    engine.submit(Request::new(
        1,
        prompt(1, 12),
        GenParams { max_new_tokens: 5, eos: None, ..Default::default() },
    ));
    let results = engine.run_until_idle().unwrap();
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].tokens.len(), 5);
    assert_eq!(results[0].finish, FinishReason::MaxTokens);
    assert!(results[0].ttft_s > 0.0);
    assert!(results[0].total_s >= results[0].ttft_s);
    // tokens must be valid vocab ids
    let vocab = engine.info().vocab as i32;
    assert!(results[0].tokens.iter().all(|&t| (0..vocab).contains(&t)));
    });
}

#[test]
fn greedy_generation_is_deterministic() {
    with_engine(|engine| {
    let mut outs = Vec::new();
    for round in 0..2 {
        engine.submit(Request::new(
            10 + round,
            prompt(7, 16),
            GenParams { max_new_tokens: 6, eos: None, ..Default::default() },
        ));
        let r = engine.run_until_idle().unwrap();
        outs.push(r[0].tokens.clone());
    }
    assert_eq!(outs[0], outs[1], "greedy decode must be reproducible");
    });
}

#[test]
fn continuous_batching_shares_decode_steps() {
    with_engine(|engine| {
    let n = 4; // == decode bucket
    for i in 0..n {
        engine.submit(Request::new(
            i,
            prompt(i as i32, 10),
            GenParams { max_new_tokens: 8, eos: None, ..Default::default() },
        ));
    }
    let results = engine.run_until_idle().unwrap();
    assert_eq!(results.len(), n as usize);
    // 4 sequences x 8 tokens; the first token comes from prefill, so
    // decode steps must be ~7, NOT ~28 — that's continuous batching.
    assert!(
        engine.metrics.decode_steps <= 9,
        "decode steps {} should be shared across the batch",
        engine.metrics.decode_steps
    );
    });
}

#[test]
fn more_requests_than_slots_all_complete() {
    with_engine(|engine| {
    for i in 0..7 {
        assert!(engine.submit(Request::new(
            i,
            prompt(i as i32 + 3, 8),
            GenParams { max_new_tokens: 4, eos: None, ..Default::default() },
        )));
    }
    let results = engine.run_until_idle().unwrap();
    assert_eq!(results.len(), 7);
    assert!(results
        .iter()
        .all(|r| r.finish == FinishReason::MaxTokens));
    });
}

#[test]
fn oversize_prompt_is_rejected_cleanly() {
    with_engine(|engine| {
    engine.submit(Request::new(1, prompt(0, 1000), GenParams::default()));
    engine.submit(Request::new(
        2,
        prompt(0, 8),
        GenParams { max_new_tokens: 2, eos: None, ..Default::default() },
    ));
    let results = engine.run_until_idle().unwrap();
    assert_eq!(results.len(), 2);
    let rejected = results.iter().find(|r| r.id == 1).unwrap();
    assert_eq!(rejected.finish, FinishReason::Rejected);
    let ok = results.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(ok.finish, FinishReason::MaxTokens);
    });
}

#[test]
fn queue_backpressure_sheds() {
    with_engine(|engine| {
    let mut accepted = 0;
    for i in 0..20 {
        if engine.submit(Request::new(i, prompt(1, 8), GenParams::default()))
        {
            accepted += 1;
        }
    }
    assert_eq!(accepted, 8, "max_queue=8 must shed the rest");
    // drain so later tests see an empty queue
    let _ = engine.run_until_idle().unwrap();
    });
}

#[test]
fn service_handles_concurrent_callers() {
    with_engine(|_shared| {
    let svc = EngineService::spawn(opts("fp")).unwrap();
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let h = svc.handle.clone();
            std::thread::spawn(move || {
                h.generate(
                    prompt(i, 10),
                    GenParams {
                        max_new_tokens: 4,
                        eos: None,
                        ..Default::default()
                    },
                )
                .unwrap()
            })
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        assert_eq!(r.tokens.len(), 4);
    }
    let stats = svc.handle.stats().unwrap();
    assert!(stats.contains("completed=6"), "stats: {stats}");
    svc.shutdown();
    });
}

#[test]
fn variant_engines_agree_on_next_token() {
    // all bit widths serve the same model: greedy first tokens should
    // agree between FP and W8A8 on an in-distribution prompt
    let p: Vec<i32> = vec![1, 3, 220, 150, 3, 80, 12];
    let params =
        GenParams { max_new_tokens: 3, eos: None, ..Default::default() };
    let fp_first = with_engine(|engine| {
        engine.submit(Request::new(1, p.clone(), params.clone()));
        engine.run_until_idle().unwrap()[0].tokens[0]
    });
    let w8_first = with_engine(|_shared| {
        // hold the lock so only one extra PJRT client exists at a time
        let mut engine = Engine::new(opts("w8a8")).unwrap();
        engine.submit(Request::new(1, p.clone(), params.clone()));
        engine.run_until_idle().unwrap()[0].tokens[0]
    });
    assert_eq!(
        fp_first, w8_first,
        "fp vs w8a8 diverge on the first greedy token"
    );
}
