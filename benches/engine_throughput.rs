//! `cargo bench --bench engine_throughput` — measured serving throughput
//! of the full coordinator per bit-width variant (the measured analogue
//! of Fig. 6 on this CPU testbed).

use odyssey::coordinator::{Engine, EngineOptions, GenParams, Request};
use odyssey::exp::eval::load_corpus;
use odyssey::quant::QuantRecipe;
use odyssey::util::XorShift;

fn main() {
    odyssey::util::log::init_from_env();
    odyssey::runtime::synth::ensure_artifacts("artifacts").expect("artifacts");
    let corpus = load_corpus("artifacts", "val").expect("corpus");
    let mut rng = XorShift::new(42);
    let trace: Vec<Vec<i32>> = (0..8)
        .map(|_| {
            let start = rng.range(0, (corpus.len() - 96) as i64) as usize;
            corpus[start..start + 48].iter().map(|&t| t as i32).collect()
        })
        .collect();

    println!(
        "{:<12} {:>12} {:>14} {:>14} {:>12}",
        "variant", "tok/s", "prefill t/s", "decode t/s", "ttft p50 ms"
    );
    for variant in ["fp", "w8a8", "w4a8_fast"] {
        // vanilla recipes: this bench measures ENGINE speed, not quality
        let recipe = match variant {
            "w8a8" => QuantRecipe::smoothquant_w8(),
            "w4a16" | "w4a8_group" => QuantRecipe::rtn_grouped(0),
            _ => QuantRecipe::vanilla_w4(),
        };
        let mut engine = Engine::new(EngineOptions {
            variant: variant.into(),
            recipe,
            ..Default::default()
        })
        .expect("engine");
        for (i, p) in trace.iter().enumerate() {
            engine.submit(Request::new(
                i as u64,
                p.clone(),
                GenParams { max_new_tokens: 8, ..Default::default() },
            ));
        }
        let t0 = std::time::Instant::now();
        let results = engine.run_until_idle().expect("run");
        let wall = t0.elapsed().as_secs_f64();
        let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        println!(
            "{:<12} {:>12.1} {:>14.1} {:>14.1} {:>12.1}",
            variant,
            tokens as f64 / wall,
            engine.metrics.prefill_tps(),
            engine.metrics.decode_tps(),
            engine.metrics.ttft.p50() * 1e3,
        );
    }
    println!(
        "\n(XLA-CPU emulates int8 math; A100 tensor-core ratios come from \
         `cargo bench --bench paper_tables`)"
    );
}
