//! `cargo bench --bench engine_throughput` — measured serving throughput
//! of the full coordinator per bit-width variant (the measured analogue
//! of Fig. 6 on this CPU testbed).
//!
//! Engines stage their weight tail once at construction; the staging
//! counters printed per variant prove the serving loop runs with zero
//! weight re-materializations.  A `BENCH {...}` json line per variant
//! feeds the trajectory file.

use odyssey::coordinator::{Engine, EngineOptions, GenParams, Request};
use odyssey::exp::eval::load_corpus;
use odyssey::formats::json::Json;
use odyssey::quant::QuantRecipe;
use odyssey::util::XorShift;

fn main() {
    odyssey::util::log::init_from_env();
    odyssey::runtime::synth::ensure_artifacts("artifacts").expect("artifacts");
    let corpus = load_corpus("artifacts", "val").expect("corpus");
    let mut rng = XorShift::new(42);
    let trace: Vec<Vec<i32>> = (0..8)
        .map(|_| {
            let start = rng.range(0, (corpus.len() - 96) as i64) as usize;
            corpus[start..start + 48].iter().map(|&t| t as i32).collect()
        })
        .collect();

    println!(
        "{:<12} {:>12} {:>14} {:>14} {:>12} {:>12}",
        "variant", "tok/s", "prefill t/s", "decode t/s", "ttft p50 ms",
        "stagings"
    );
    for variant in ["fp", "w8a8", "w4a8_fast"] {
        // vanilla recipes: this bench measures ENGINE speed, not quality
        let recipe = match variant {
            "w8a8" => QuantRecipe::smoothquant_w8(),
            "w4a16" | "w4a8_group" => QuantRecipe::rtn_grouped(0),
            _ => QuantRecipe::vanilla_w4(),
        };
        let mut engine = Engine::new(EngineOptions {
            variant: variant.into(),
            recipe,
            ..Default::default()
        })
        .expect("engine");
        for (i, p) in trace.iter().enumerate() {
            engine.submit(Request::new(
                i as u64,
                p.clone(),
                GenParams { max_new_tokens: 8, ..Default::default() },
            ));
        }
        let t0 = std::time::Instant::now();
        let results = engine.run_until_idle().expect("run");
        let wall = t0.elapsed().as_secs_f64();
        let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        let stats = engine.staging_stats();
        println!(
            "{:<12} {:>12.1} {:>14.1} {:>14.1} {:>12.1} {:>12}",
            variant,
            tokens as f64 / wall,
            engine.metrics.prefill_tps(),
            engine.metrics.decode_tps(),
            engine.metrics.ttft.p50() * 1e3,
            stats.stage_calls,
        );
        // a staged engine must not re-materialize weights while serving
        if stats.stage_calls > 0 {
            assert_eq!(
                stats.weight_bytes_rematerialized, 0,
                "{variant}: serving loop re-materialized weight bytes"
            );
        }
        let bench = Json::obj(vec![
            ("bench", Json::Str("engine_throughput".into())),
            ("variant", Json::Str(variant.into())),
            ("tok_per_s", Json::Num(tokens as f64 / wall)),
            ("decode_tps", Json::Num(engine.metrics.decode_tps())),
            ("ttft_p50_ms", Json::Num(engine.metrics.ttft.p50() * 1e3)),
            ("stage_calls", Json::Num(stats.stage_calls as f64)),
            ("staged_execs", Json::Num(stats.staged_execs as f64)),
            (
                "weight_bytes_rematerialized",
                Json::Num(stats.weight_bytes_rematerialized as f64),
            ),
        ]);
        println!("BENCH {}", bench.emit());
    }
    println!(
        "\n(XLA-CPU emulates int8 math; A100 tensor-core ratios come from \
         `cargo bench --bench paper_tables`)"
    );
}
