//! `cargo bench --bench gemm_kernels` — measured per-kernel latencies of
//! every GEMM paradigm through the compiled AOT graphs (cpu shape set).
//!
//! This is the measured half of Fig. 7 / Table 5: the ordering
//! (fastgemm <= w8a8 < grouped/asym at M=1; unfused > fast) cross-checks
//! the A100 model's structural claims on real executables.
//!
//! Weights are STAGED once per graph (same discipline as
//! `exp::latency::measured_gemm_set`): timed iterations pass only the
//! activation head, while in-kernel conversion costs — FastGEMM's fused
//! x16 unpack vs the unfused baseline's value recovery — stay inside
//! the measured region, keeping the fusion ablation apples-to-apples.

use odyssey::exp::latency::random_gemm_args;
use odyssey::runtime::{Literal, Runtime};
use odyssey::util::Bencher;

fn main() {
    odyssey::util::log::init_from_env();
    odyssey::runtime::synth::ensure_artifacts("artifacts").expect("artifacts");
    let mut rt = Runtime::new("artifacts").expect("runtime");
    let graphs: Vec<_> =
        rt.manifest.gemm_graphs("cpu").into_iter().cloned().collect();

    // decode-like shapes (M=1) for every variant; context (M=1024) for a
    // fast subset so the bench stays under a few minutes.
    let mut rows = Vec::new();
    for gi in &graphs {
        let heavy = gi.m > 1;
        if heavy
            && !matches!(gi.variant.as_str(), "w4a8_fast" | "w8a8" | "fp")
        {
            continue;
        }
        if heavy && gi.n * gi.k > 1024 * 1024 {
            continue; // keep context-stage benches to the smallest shape
        }
        let args = random_gemm_args(&gi.params).expect("args");
        let n_dyn = gi
            .dynamic_param_count(&rt.manifest)
            .expect("argument classes");
        let weights: Vec<(&str, &Literal)> = gi.params[n_dyn..]
            .iter()
            .map(|p| p.name.as_str())
            .zip(args[n_dyn..].iter())
            .collect();
        let staged = rt.stage(&gi.name, &weights).expect("stage");
        let dynamic: Vec<&Literal> = args[..n_dyn].iter().collect();
        let mut b = Bencher::new(&gi.name).with_budget(1.0).with_iters(3, 30);
        let res = b.run(|| {
            rt.run_staged(&staged, &dynamic).expect("run");
        });
        rows.push((gi.variant.clone(), gi.m, gi.n, gi.k, res));
    }
    rows.sort_by(|a, b| (a.1, a.2, a.3, a.0.clone())
        .cmp(&(b.1, b.2, b.3, b.0.clone())));
    println!(
        "{:<16} {:>6} {:>6} {:>6} {:>12} {:>10}",
        "variant", "M", "N", "K", "mean µs", "min µs"
    );
    for (v, m, n, k, r) in &rows {
        println!(
            "{:<16} {:>6} {:>6} {:>6} {:>12.1} {:>10.1}",
            v,
            m,
            n,
            k,
            r.mean_s * 1e6,
            r.min_s * 1e6
        );
    }

    // headline ratios at the M=1 (self-decode) 1024x1024 shape
    let t = |variant: &str| {
        rows.iter()
            .find(|(v, m, n, k, _)| v == variant && *m == 1 && *n == 1024
                  && *k == 1024)
            .map(|(_, _, _, _, r)| r.mean_s)
    };
    if let (Some(fast), Some(unfused)) = (t("w4a8_fast"), t("w4a8_unfused"))
    {
        println!(
            "\nfusion ablation (Fig.4 b vs c) @ M=1 1024x1024: \
             unfused/fused = {:.2}x",
            unfused / fast
        );
    }
    if let (Some(fast), Some(group)) = (t("w4a8_fast"), t("w4a8_group")) {
        println!(
            "fine-grained vs FastGEMM @ M=1 1024x1024: {:.2}x",
            group / fast
        );
    }
}
