//! `cargo bench --bench gemm_kernels` — measured per-kernel latencies of
//! every GEMM paradigm through the compiled AOT graphs (cpu shape set).
//!
//! This is the measured half of Fig. 7 / Table 5: the ordering
//! (fastgemm <= w8a8 < grouped/asym at M=1; unfused > fast) cross-checks
//! the A100 model's structural claims on real executables.
//!
//! Weights are STAGED once per graph (same discipline as
//! `exp::latency::measured_gemm_set`): timed iterations pass only the
//! activation head, while in-kernel conversion costs — FastGEMM's fused
//! x16 unpack vs the unfused baseline's value recovery — stay inside
//! the measured region, keeping the fusion ablation apples-to-apples.

use odyssey::exp::latency::random_gemm_args;
use odyssey::formats::json::Json;
use odyssey::kernels::{kernel_set, KernelChoice};
use odyssey::quant::{pack, rtn, scale};
use odyssey::runtime::{Literal, Runtime};
use odyssey::tensor::Tensor;
use odyssey::util::{merge_bench_records, Bencher};

fn main() {
    odyssey::util::log::init_from_env();
    odyssey::runtime::synth::ensure_artifacts("artifacts").expect("artifacts");
    let mut rt = Runtime::new("artifacts").expect("runtime");
    let graphs: Vec<_> =
        rt.manifest.gemm_graphs("cpu").into_iter().cloned().collect();

    // decode-like shapes (M=1) for every variant; context (M=1024) for a
    // fast subset so the bench stays under a few minutes.
    let mut rows = Vec::new();
    for gi in &graphs {
        let heavy = gi.m > 1;
        if heavy
            && !matches!(gi.variant.as_str(), "w4a8_fast" | "w8a8" | "fp")
        {
            continue;
        }
        if heavy && gi.n * gi.k > 1024 * 1024 {
            continue; // keep context-stage benches to the smallest shape
        }
        let args = random_gemm_args(&gi.params).expect("args");
        let n_dyn = gi
            .dynamic_param_count(&rt.manifest)
            .expect("argument classes");
        let weights: Vec<(&str, &Literal)> = gi.params[n_dyn..]
            .iter()
            .map(|p| p.name.as_str())
            .zip(args[n_dyn..].iter())
            .collect();
        let staged = rt.stage(&gi.name, &weights).expect("stage");
        let dynamic: Vec<&Literal> = args[..n_dyn].iter().collect();
        let mut b = Bencher::new(&gi.name).with_budget(1.0).with_iters(3, 30);
        let res = b.run(|| {
            rt.run_staged(&staged, &dynamic).expect("run");
        });
        rows.push((gi.variant.clone(), gi.m, gi.n, gi.k, res));
    }
    rows.sort_by(|a, b| (a.1, a.2, a.3, a.0.clone())
        .cmp(&(b.1, b.2, b.3, b.0.clone())));
    println!(
        "{:<16} {:>6} {:>6} {:>6} {:>12} {:>10}",
        "variant", "M", "N", "K", "mean µs", "min µs"
    );
    for (v, m, n, k, r) in &rows {
        println!(
            "{:<16} {:>6} {:>6} {:>6} {:>12.1} {:>10.1}",
            v,
            m,
            n,
            k,
            r.mean_s * 1e6,
            r.min_s * 1e6
        );
    }

    // headline ratios at the M=1 (self-decode) 1024x1024 shape
    let t = |variant: &str| {
        rows.iter()
            .find(|(v, m, n, k, _)| v == variant && *m == 1 && *n == 1024
                  && *k == 1024)
            .map(|(_, _, _, _, r)| r.mean_s)
    };
    if let (Some(fast), Some(unfused)) = (t("w4a8_fast"), t("w4a8_unfused"))
    {
        println!(
            "\nfusion ablation (Fig.4 b vs c) @ M=1 1024x1024: \
             unfused/fused = {:.2}x",
            unfused / fast
        );
    }
    if let (Some(fast), Some(group)) = (t("w4a8_fast"), t("w4a8_group")) {
        println!(
            "fine-grained vs FastGEMM @ M=1 1024x1024: {:.2}x",
            group / fast
        );
    }

    // ---- kernel-set sweep: the SAME fp / w8a8 / w4a8_fast GEMMs run
    // straight through each dispatch set (scalar reference, cache-
    // blocked, threadpool-parallel) at a prefill-slab shape.  Parity is
    // asserted BEFORE timing — the GFLOP/s column only means anything
    // because the outputs are bit-identical — and the section lands in
    // BENCH_kernels.json (the committed trajectory file).
    let smoke = matches!(
        std::env::var("ODYSSEY_BENCH_SMOKE").as_deref(),
        Ok("1") | Ok("true")
    );
    let (m, n, k) =
        if smoke { (32, 256, 256) } else { (256, 1024, 1024) };
    let budget = if smoke { 0.2 } else { 1.0 };
    let (it_min, it_max) = if smoke { (2, 4) } else { (3, 20) };
    let x = Tensor::randn(&[m, k], 7);
    let wf = Tensor::randn(&[k, n], 11);
    let (xq, s_a) = scale::quant_act_per_token(&x);
    let (w8, s_w8) = rtn::rtn_per_channel(&wf, 8, None, None);
    let (w4, s_w4) = rtn::rtn_per_channel(&wf, 4, None, None);
    let wp = pack::pack_int4(&w4);
    let flops = 2.0 * (m * n * k) as f64;

    let reference = kernel_set(KernelChoice::Scalar);
    let ref_fp = reference.gemm_fp(&x, &wf);
    let ref_w8 = reference.gemm_w8a8(&xq, &s_a, &w8, &s_w8);
    let ref_fast = reference.gemm_w4a8_fast(&xq, &s_a, &wp, &s_w4);

    println!(
        "\nkernel-set sweep @ {m}x{n}x{k} (GFLOP/s from min time)"
    );
    println!(
        "{:<10} {:<12} {:>10} {:>10}",
        "set", "variant", "min µs", "GFLOP/s"
    );
    let mut records = Vec::new();
    let mut w8a8_min = Vec::new();
    for choice in
        [KernelChoice::Scalar, KernelChoice::Blocked, KernelChoice::Parallel]
    {
        let ks = kernel_set(choice);
        assert_eq!(
            ks.gemm_fp(&x, &wf),
            ref_fp,
            "{}: fp output differs from scalar",
            ks.name()
        );
        assert_eq!(
            ks.gemm_w8a8(&xq, &s_a, &w8, &s_w8),
            ref_w8,
            "{}: w8a8 output differs from scalar",
            ks.name()
        );
        assert_eq!(
            ks.gemm_w4a8_fast(&xq, &s_a, &wp, &s_w4),
            ref_fast,
            "{}: w4a8_fast output differs from scalar",
            ks.name()
        );
        let runs: [(&str, Box<dyn FnMut() + '_>); 3] = [
            (
                "fp",
                Box::new(|| {
                    std::hint::black_box(ks.gemm_fp(&x, &wf));
                }),
            ),
            (
                "w8a8",
                Box::new(|| {
                    std::hint::black_box(
                        ks.gemm_w8a8(&xq, &s_a, &w8, &s_w8),
                    );
                }),
            ),
            (
                "w4a8_fast",
                Box::new(|| {
                    std::hint::black_box(
                        ks.gemm_w4a8_fast(&xq, &s_a, &wp, &s_w4),
                    );
                }),
            ),
        ];
        for (variant, mut f) in runs {
            let r = Bencher::new(&format!("{} {variant}", ks.name()))
                .with_budget(budget)
                .with_iters(it_min, it_max)
                .run(&mut *f);
            let gflops = flops / r.min_s / 1e9;
            println!(
                "{:<10} {:<12} {:>10.1} {:>10.2}",
                ks.name(),
                variant,
                r.min_s * 1e6,
                gflops
            );
            if variant == "w8a8" {
                w8a8_min.push((ks.name(), r.min_s));
            }
            records.push(Json::obj(vec![
                ("bench", Json::Str("gemm_kernels".into())),
                ("kernels", Json::Str(ks.name().into())),
                ("variant", Json::Str(variant.into())),
                ("m", Json::Num(m as f64)),
                ("n", Json::Num(n as f64)),
                ("k", Json::Num(k as f64)),
                ("min_us", Json::Num(r.min_s * 1e6)),
                ("gflops", Json::Num(gflops)),
            ]));
        }
    }

    let min_of = |set: &str| {
        w8a8_min
            .iter()
            .find(|(s, _)| *s == set)
            .map(|(_, t)| *t)
            .expect("w8a8 timing")
    };
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    let speedup = min_of("scalar") / min_of("parallel");
    println!(
        "parallel vs scalar w8a8 @ {m}x{n}x{k}: {speedup:.2}x \
         ({cores} cores)"
    );
    // acceptance guard: on a real multi-core runner the parallel set
    // must clear 2x over the scalar reference at the full bench shape
    // (smoke shapes are too small to amortize the fork/join)
    if !smoke && cores >= 4 {
        assert!(
            speedup >= 2.0,
            "parallel w8a8 only {speedup:.2}x over scalar on \
             {cores} cores (want >= 2x)"
        );
    }
    merge_bench_records("BENCH_kernels.json", "gemm_kernels", &records)
        .expect("write BENCH_kernels.json");
    for r in &records {
        println!("BENCH {}", r.emit());
    }
}
