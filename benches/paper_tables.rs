//! `cargo bench --bench paper_tables` — regenerates every latency table
//! and figure of the paper from the analytical A100 model:
//! Fig. 1, Fig. 6, Fig. 7 (model half), Tables 4, 5 (model half), 7.
//!
//! Pure computation (no artifacts needed); the measured-CPU halves live
//! in `gemm_kernels` and `engine_throughput`.

fn main() {
    odyssey::util::log::init_from_env();
    // measured halves (fig7/tab5) need artifacts; synthesize if absent
    let _ = odyssey::runtime::synth::ensure_artifacts("artifacts");
    for exp in ["fig1", "fig6", "tab4", "tab7"] {
        println!("\n================ {exp} ================");
        // these experiments are perfmodel-only: no artifacts required
        odyssey::exp::run(exp, "artifacts").expect(exp);
    }
    // fig7/tab5 include measured halves that need artifacts; run the
    // model halves here unconditionally and the measured halves only if
    // artifacts exist.
    let have_artifacts =
        std::path::Path::new("artifacts/manifest.json").exists();
    if have_artifacts {
        for exp in ["fig7", "tab5"] {
            println!("\n================ {exp} ================");
            odyssey::exp::run(exp, "artifacts").expect(exp);
        }
    } else {
        println!("\n(artifacts missing: skipped measured fig7/tab5 halves)");
    }
}
