//! `cargo bench --bench quantizer` — offline-tooling performance: RTN /
//! LWC / GPTQ wall time per matrix size.  Not a paper table, but the
//! quantization pass is part of the deploy story (PTQ cost, Sec. 6.2
//! "low-cost benefit").

use odyssey::quant::{gptq, lwc, rtn, GptqConfig};
use odyssey::tensor::Tensor;
use odyssey::util::Bencher;

fn main() {
    for (k, n) in [(256usize, 256usize), (256, 768), (768, 256)] {
        let w = Tensor::randn(&[k, n], 1);
        let x = Tensor::randn(&[256, k], 2);
        let xt = x.transpose();
        let h = xt.matmul(&x).map(|v| 2.0 * v / 256.0);

        let r = Bencher::new(&format!("rtn_pc4       {k}x{n}"))
            .with_budget(0.5)
            .run(|| {
                let _ = rtn::rtn_per_channel(&w, 4, None, None);
            });
        println!("{r}");
        let r = Bencher::new(&format!("lwc_grid      {k}x{n}"))
            .with_budget(1.5)
            .with_iters(2, 10)
            .run(|| {
                let _ = lwc::lwc(&w, 4);
            });
        println!("{r}");
        let r = Bencher::new(&format!("gptq          {k}x{n}"))
            .with_budget(1.5)
            .with_iters(2, 10)
            .run(|| {
                let _ = gptq::gptq_quantize(
                    &w,
                    &h,
                    &GptqConfig::default(),
                    None,
                )
                .unwrap();
            });
        println!("{r}");
    }
}
