//! `cargo bench --bench hot_loop` — the L3 §Perf ablation: decode-step
//! cost under the legacy arg path (clone every weight literal + rebuild
//! KV from host arrays + parse the full output tuple) vs the optimized
//! path (borrowed weight literals + KV literal reuse + logits-only
//! parse).  Documents the EXPERIMENTS.md §Perf before/after.

use odyssey::model::{self, Checkpoint};
use odyssey::quant::QuantRecipe;
use odyssey::runtime::{self, Literal, Runtime};
use odyssey::util::Bencher;

fn main() {
    odyssey::util::log::init_from_env();
    let artifacts = "artifacts";
    odyssey::runtime::synth::ensure_artifacts(artifacts).expect("artifacts");
    for variant in ["w4a8_fast", "fp"] {
        let mut rt = Runtime::new(artifacts).expect("make artifacts first");
        let info = rt.manifest.model("tiny3m").unwrap().clone();
        let ckpt = Checkpoint::load(&rt.manifest, "tiny3m").unwrap();
        let qw = model::quantize_checkpoint(
            &ckpt,
            None,
            &QuantRecipe::vanilla_w4(),
            variant,
            rt.manifest.group_size,
        )
        .unwrap();
        let weights: Vec<Literal> = qw
            .tensors
            .iter()
            .map(|t| runtime::literal_from_st(t).unwrap())
            .collect();
        let graph = format!("tiny3m_{variant}_decode_b4");
        rt.executable(&graph).expect("compile");

        let b = 4usize;
        let (h, s, d) = (info.n_heads, info.max_seq, info.head_dim);
        let kv_shape = [b, h, s, d];
        let cache_len: usize = kv_shape.iter().product();
        let kv_host: Vec<Vec<f32>> =
            (0..2 * info.n_layers).map(|_| vec![0f32; cache_len]).collect();
        let token = runtime::literal_i32(&[b], &[5, 6, 7, 8]).unwrap();
        let pos = runtime::literal_i32(&[b], &[3, 3, 3, 3]).unwrap();

        // ---- legacy path: clones + host KV rebuild + full parse
        let legacy = Bencher::new(&format!("{variant} legacy decode step"))
            .with_budget(4.0)
            .with_iters(4, 30)
            .run(|| {
                let mut args =
                    Vec::with_capacity(2 + kv_host.len() + weights.len());
                args.push(token.clone());
                args.push(pos.clone());
                for kvv in &kv_host {
                    args.push(
                        runtime::literal_f32(&kv_shape, kvv).unwrap(),
                    );
                }
                args.extend(weights.iter().cloned());
                let outs = rt.run_literals(&graph, &args).unwrap();
                // parse EVERY output to f32 (the old adopt path)
                for o in &outs {
                    let _ = o.to_vec::<f32>().unwrap();
                }
            });
        println!("{legacy}");

        // ---- optimized path: refs + KV literal reuse + logits-only parse
        let mut kv_lits: Vec<Literal> = kv_host
            .iter()
            .map(|v| runtime::literal_f32(&kv_shape, v).unwrap())
            .collect();
        let optimized =
            Bencher::new(&format!("{variant} optimized decode step"))
                .with_budget(4.0)
                .with_iters(4, 30)
                .run(|| {
                    let mut args: Vec<&Literal> = Vec::with_capacity(
                        2 + kv_lits.len() + weights.len(),
                    );
                    args.push(&token);
                    args.push(&pos);
                    args.extend(kv_lits.iter());
                    args.extend(weights.iter());
                    let mut outs =
                        rt.run_literal_refs(&graph, &args).unwrap();
                    let _ = outs[0].to_vec::<f32>().unwrap(); // logits only
                    kv_lits = outs.split_off(1); // reuse next step
                });
        println!("{optimized}");
        println!(
            "{variant}: speedup {:.2}x (coordinator overhead removed: {:.2} ms/step)\n",
            legacy.mean_s / optimized.mean_s,
            (legacy.mean_s - optimized.mean_s) * 1e3
        );
    }
}
