//! `cargo bench --bench hot_loop` — the L3 §Perf ablation: decode-step
//! cost under four argument disciplines:
//!
//! 1. legacy — clone every weight literal + rebuild KV from host arrays
//!    + parse the full output tuple;
//! 2. optimized — borrowed weight literals + KV literal reuse +
//!    logits-only parse (weights still re-materialized inside the
//!    backend every step);
//! 3. staged — `Runtime::stage` materializes the weight tail ONCE, each
//!    step passes only `[token, pos, KV...]` (`Runtime::run_staged`);
//! 4. paged — staged weights AND paged KV: history is read through
//!    block tables and the new token's K/V lands in the pool in place
//!    (`Runtime::run_decode_paged`), so the full `[B, H, max_seq, Dh]`
//!    caches stop crossing the execution boundary entirely.
//!
//! Besides timings, the staging counters report the weight bytes AND
//! the KV-cache bytes each discipline moves per decode step — the
//! regression signals for the prepare-once API and the paged pool —
//! and a machine-readable `BENCH {...}` json line per variant feeds
//! the trajectory file (CI uploads it as an artifact).
//!
//! `ODYSSEY_BENCH_SMOKE=1` shrinks budgets/iterations for CI smoke
//! runs; the counters and regression guards still apply.

use odyssey::coordinator::{Engine, EngineOptions, GenParams, Request};
use odyssey::formats::json::Json;
use odyssey::kernels::KernelChoice;
use odyssey::model::{self, Checkpoint};
use odyssey::quant::QuantRecipe;
use odyssey::runtime::{self, KvBlockPool, KvDtype, Literal, Runtime};
use odyssey::util::{merge_bench_records, Bencher};

fn main() {
    odyssey::util::log::init_from_env();
    let artifacts = "artifacts";
    odyssey::runtime::synth::ensure_artifacts(artifacts).expect("artifacts");
    let smoke = matches!(
        std::env::var("ODYSSEY_BENCH_SMOKE").as_deref(),
        Ok("1") | Ok("true")
    );
    let budget = if smoke { 0.25 } else { 4.0 };
    let (it_min, it_max) = if smoke { (2, 4) } else { (4, 30) };
    for variant in ["w4a8_fast", "fp"] {
        let mut rt = Runtime::new(artifacts).expect("make artifacts first");
        let info = rt.manifest.model("tiny3m").unwrap().clone();
        let ckpt = Checkpoint::load(&rt.manifest, "tiny3m").unwrap();
        let qw = model::quantize_checkpoint(
            &ckpt,
            None,
            &QuantRecipe::vanilla_w4(),
            variant,
            rt.manifest.group_size,
        )
        .unwrap();
        let weights: Vec<Literal> = qw
            .tensors
            .iter()
            .map(|t| runtime::literal_from_st(t).unwrap())
            .collect();
        let graph = format!("tiny3m_{variant}_decode_b4");
        rt.executable(&graph).expect("compile");

        let b = 4usize;
        let (h, s, d) = (info.n_heads, info.max_seq, info.head_dim);
        let kv_shape = [b, h, s, d];
        let cache_len: usize = kv_shape.iter().product();
        let kv_host: Vec<Vec<f32>> =
            (0..2 * info.n_layers).map(|_| vec![0f32; cache_len]).collect();
        let token = runtime::literal_i32(&[b], &[5, 6, 7, 8]).unwrap();
        let pos = runtime::literal_i32(&[b], &[3, 3, 3, 3]).unwrap();

        // ---- legacy path: clones + host KV rebuild + full parse
        let stats0 = rt.staging_stats();
        let legacy = Bencher::new(&format!("{variant} legacy decode step"))
            .with_budget(budget)
            .with_iters(it_min, it_max)
            .run(|| {
                let mut args =
                    Vec::with_capacity(2 + kv_host.len() + weights.len());
                args.push(token.clone());
                args.push(pos.clone());
                for kvv in &kv_host {
                    args.push(
                        runtime::literal_f32(&kv_shape, kvv).unwrap(),
                    );
                }
                args.extend(weights.iter().cloned());
                let outs = rt.run_literals(&graph, &args).unwrap();
                // parse EVERY output to f32 (the old adopt path)
                for o in &outs {
                    let _ = o.to_vec::<f32>().unwrap();
                }
            });
        println!("{legacy}");
        let stats1 = rt.staging_stats();
        let unstaged_bytes_per_step = (stats1.weight_bytes_rematerialized
            - stats0.weight_bytes_rematerialized)
            / (stats1.unstaged_execs - stats0.unstaged_execs).max(1);

        // ---- optimized path: refs + KV literal reuse + logits-only parse
        let mut kv_lits: Vec<Literal> = kv_host
            .iter()
            .map(|v| runtime::literal_f32(&kv_shape, v).unwrap())
            .collect();
        let optimized =
            Bencher::new(&format!("{variant} optimized decode step"))
                .with_budget(budget)
                .with_iters(it_min, it_max)
                .run(|| {
                    let mut args: Vec<&Literal> = Vec::with_capacity(
                        2 + kv_lits.len() + weights.len(),
                    );
                    args.push(&token);
                    args.push(&pos);
                    args.extend(kv_lits.iter());
                    args.extend(weights.iter());
                    let mut outs =
                        rt.run_literal_refs(&graph, &args).unwrap();
                    let _ = outs[0].to_vec::<f32>().unwrap(); // logits only
                    kv_lits = outs.split_off(1); // reuse next step
                });
        println!("{optimized}");

        // ---- staged path: weight tail staged ONCE, dynamic args only
        let pairs: Vec<(&str, &Literal)> = qw
            .names
            .iter()
            .map(String::as_str)
            .zip(weights.iter())
            .collect();
        let staged = rt.stage(&graph, &pairs).unwrap();
        let mut kv_staged: Vec<Literal> = kv_host
            .iter()
            .map(|v| runtime::literal_f32(&kv_shape, v).unwrap())
            .collect();
        let stats2 = rt.staging_stats();
        let staged_res =
            Bencher::new(&format!("{variant} staged decode step"))
                .with_budget(budget)
                .with_iters(it_min, it_max)
                .run(|| {
                    let mut dynamic: Vec<&Literal> =
                        Vec::with_capacity(2 + kv_staged.len());
                    dynamic.push(&token);
                    dynamic.push(&pos);
                    dynamic.extend(kv_staged.iter());
                    let mut outs = rt.run_staged(&staged, &dynamic).unwrap();
                    let _ = outs[0].to_vec::<f32>().unwrap(); // logits only
                    kv_staged = outs.split_off(1); // reuse next step
                });
        println!("{staged_res}");
        let stats3 = rt.staging_stats();
        // regression guard: staged steps must re-materialize NOTHING
        let staged_bytes_total = stats3.weight_bytes_rematerialized
            - stats2.weight_bytes_rematerialized;
        assert_eq!(
            staged_bytes_total, 0,
            "staged decode steps re-materialized weight bytes"
        );
        assert_eq!(
            stats3.stage_calls,
            stats2.stage_calls,
            "staged decode steps re-staged weights"
        );
        // contiguous decode still hauls the full caches both ways
        let kv_bytes_contiguous = (stats3.kv_bytes_moved
            - stats2.kv_bytes_moved)
            / (stats3.staged_execs - stats2.staged_execs).max(1);

        // ---- paged path: block tables + in-place pool writes.  The
        // serving win scenario: sequences at prompt_len ≪ max_seq.
        let prompt_len = 16usize;
        let bs_kv = 16usize;
        let n_blocks = b * info.max_seq.div_ceil(bs_kv);
        let blocks_per_row = n_blocks / b;
        let mut pool =
            KvBlockPool::new(n_blocks, bs_kv, info.n_layers, h, d);
        // each row owns a fixed stripe of blocks covering max_seq
        let tables: Vec<Vec<u32>> = (0..b)
            .map(|bi| {
                ((bi * blocks_per_row) as u32
                    ..((bi + 1) * blocks_per_row) as u32)
                    .collect()
            })
            .collect();
        let token_p = [5i32, 6, 7, 8];
        let pos_p = [prompt_len as i32; 4];
        let stats4 = rt.staging_stats();
        let paged_res =
            Bencher::new(&format!("{variant} paged decode step"))
                .with_budget(budget)
                .with_iters(it_min, it_max)
                .run(|| {
                    let tbl: Vec<&[u32]> =
                        tables.iter().map(|t| t.as_slice()).collect();
                    let out = rt
                        .run_decode_paged(
                            &staged, &token_p, &pos_p, &mut pool, &tbl,
                        )
                        .unwrap();
                    let _ = out.to_vec::<f32>().unwrap(); // logits only
                });
        println!("{paged_res}");
        let stats5 = rt.staging_stats();
        let paged_steps =
            (stats5.paged_decode_steps - stats4.paged_decode_steps).max(1);
        let kv_bytes_paged =
            (stats5.kv_bytes_moved - stats4.kv_bytes_moved) / paged_steps;
        // acceptance guard: at prompt_len ≪ max_seq the paged path must
        // move far fewer KV bytes per decode step than the contiguous
        // path (it only writes the new token's rows)
        assert!(
            kv_bytes_paged < kv_bytes_contiguous,
            "paged decode moved {kv_bytes_paged} KV bytes/step, \
             contiguous {kv_bytes_contiguous}"
        );

        println!(
            "{variant}: staged speedup vs legacy {:.2}x, vs optimized {:.2}x \
             (weight bytes/step: {unstaged_bytes_per_step} -> 0; staged \
             once: {} bytes; KV bytes/step: {kv_bytes_contiguous} \
             contiguous -> {kv_bytes_paged} paged, {:.0}x less)\n",
            legacy.mean_s / staged_res.mean_s,
            optimized.mean_s / staged_res.mean_s,
            staged.weight_bytes(),
            kv_bytes_contiguous as f64 / kv_bytes_paged.max(1) as f64,
        );

        let bench = Json::obj(vec![
            ("bench", Json::Str("hot_loop".into())),
            ("variant", Json::Str(variant.into())),
            ("legacy_ms", Json::Num(legacy.mean_s * 1e3)),
            ("optimized_ms", Json::Num(optimized.mean_s * 1e3)),
            ("staged_ms", Json::Num(staged_res.mean_s * 1e3)),
            ("paged_ms", Json::Num(paged_res.mean_s * 1e3)),
            (
                "weight_bytes_per_step_unstaged",
                Json::Num(unstaged_bytes_per_step as f64),
            ),
            ("weight_bytes_per_step_staged", Json::Num(0.0)),
            (
                "staged_weight_bytes",
                Json::Num(staged.weight_bytes() as f64),
            ),
            (
                "kv_bytes_per_step_contiguous",
                Json::Num(kv_bytes_contiguous as f64),
            ),
            (
                "kv_bytes_per_step_paged",
                Json::Num(kv_bytes_paged as f64),
            ),
            (
                "speedup_vs_legacy",
                Json::Num(legacy.mean_s / staged_res.mean_s),
            ),
        ]);
        println!("BENCH {}", bench.emit());
    }

    // ---- prefix cache: engine-level shared-prompt scenario.  Six
    // requests share one prompt; the cache-on run must skip >= 50% of
    // the batch's prefill tokens and allocate strictly fewer KV
    // blocks than cache-off, with bit-identical token streams — the
    // serving-layer half of the speed story (shared prefixes cut
    // prefill work, W4A8 cuts per-token cost).
    let shared_prompt: Vec<i32> =
        (0..16).map(|i| 3 + (i * 7) % 500).collect();
    let run_engine = |prefix: bool| {
        let mut o = EngineOptions {
            variant: "fp".into(),
            recipe: QuantRecipe::vanilla_w4(),
            prefill_batch: 1,
            max_queue: 16,
            ..Default::default()
        };
        o.paged = true;
        o.staging = true;
        o.prefix_cache = prefix;
        o.kv_block_size = 4;
        o.kv_blocks = Some(28);
        let mut engine = Engine::new(o).expect("engine");
        for i in 0..6u64 {
            engine.submit(Request::new(
                i,
                shared_prompt.clone(),
                GenParams {
                    max_new_tokens: 4,
                    eos: None,
                    ..Default::default()
                },
            ));
        }
        let t0 = std::time::Instant::now();
        let mut results = engine.run_until_idle().expect("drain");
        let dt = t0.elapsed().as_secs_f64();
        results.sort_by_key(|r| r.id);
        let tokens: Vec<Vec<i32>> =
            results.into_iter().map(|r| r.tokens).collect();
        (tokens, engine, dt)
    };
    let (on_tokens, on, on_s) = run_engine(true);
    let (off_tokens, off, off_s) = run_engine(false);
    assert_eq!(
        on_tokens, off_tokens,
        "prefix cache must not change token streams"
    );
    let (m_on, m_off) = (&on.metrics, &off.metrics);
    // acceptance guards (also pinned by tests/engine_integration.rs)
    assert!(
        m_on.prefill_tokens_skipped * 2 >= m_on.prefill_tokens,
        "prefix cache skipped {}/{} prefill tokens (< 50%)",
        m_on.prefill_tokens_skipped,
        m_on.prefill_tokens
    );
    assert!(
        m_on.kv_blocks_allocated < m_off.kv_blocks_allocated,
        "cache on allocated {} blocks, cache off {}",
        m_on.kv_blocks_allocated,
        m_off.kv_blocks_allocated
    );
    println!(
        "prefix cache: {} hits, {}/{} prefill tokens skipped, {} cow \
         forks, {} shared blocks (peak), blocks allocated {} -> {} \
         (drain {:.3}s -> {:.3}s)\n",
        m_on.prefix_hits,
        m_on.prefill_tokens_skipped,
        m_on.prefill_tokens,
        m_on.cow_forks,
        m_on.shared_blocks,
        m_off.kv_blocks_allocated,
        m_on.kv_blocks_allocated,
        off_s,
        on_s,
    );
    // ---- iteration-level scheduler: long prompt + active decodes.
    // Three short requests decode while a long prompt arrives; with
    // chunking the prompt advances chunk-by-chunk under the step
    // token budget and NO decode slot ever stalls, while the legacy
    // two-phase loop stalls every active behind the whole-prompt
    // prefill.  Streams must be bit-identical; the TTFT/ITL
    // percentiles (in engine steps) and the worst decode stall land
    // in the BENCH json so the chunking tradeoff is visible in the
    // perf trajectory.  ODYSSEY_STEP_TOKEN_BUDGET sweeps the budget
    // (CI runs a small and a large leg).
    let budget_tokens = odyssey::runtime::step_token_budget_from_env()
        .unwrap_or(16);
    let long_prompt: Vec<i32> =
        (0..96).map(|i| 3 + (i * 11) % 500).collect();
    let run_sched = |chunking: bool| {
        let mut o = EngineOptions {
            variant: "fp".into(),
            recipe: QuantRecipe::vanilla_w4(),
            max_queue: 16,
            ..Default::default()
        };
        o.paged = true;
        o.staging = true;
        o.chunking = chunking;
        o.step_token_budget = budget_tokens;
        o.kv_block_size = 4;
        let mut engine = Engine::new(o).expect("engine");
        for i in 0..3u64 {
            engine.submit(Request::new(
                i,
                (0..8).map(|j| 3 + (i as i32 * 7 + j) % 500).collect(),
                GenParams {
                    max_new_tokens: 24,
                    eos: None,
                    ..Default::default()
                },
            ));
        }
        engine.step().expect("warmup step");
        engine.step().expect("warmup step");
        engine.submit(Request::new(
            10,
            long_prompt.clone(),
            GenParams { max_new_tokens: 4, eos: None, ..Default::default() },
        ));
        let t0 = std::time::Instant::now();
        let mut results = engine.run_until_idle().expect("drain");
        let dt = t0.elapsed().as_secs_f64();
        results.sort_by_key(|r| r.id);
        let tokens: Vec<Vec<i32>> =
            results.iter().map(|r| r.tokens.clone()).collect();
        (tokens, engine, dt)
    };
    let (sched_on_tokens, mut sched_on, sched_on_s) = run_sched(true);
    let (sched_off_tokens, mut sched_off, sched_off_s) = run_sched(false);
    assert_eq!(
        sched_on_tokens, sched_off_tokens,
        "chunked scheduling must not change token streams"
    );
    assert!(
        sched_on.metrics.max_decode_stall_steps
            < sched_off.metrics.max_decode_stall_steps.max(1),
        "chunking must improve the worst decode stall \
         ({} vs {} steps)",
        sched_on.metrics.max_decode_stall_steps,
        sched_off.metrics.max_decode_stall_steps
    );
    let (on_ttft_p50, on_ttft_p95, on_ttft_p99) =
        sched_on.metrics.ttft_steps_pcts();
    let (on_itl_p50, on_itl_p95, on_itl_p99) =
        sched_on.metrics.itl_steps_pcts();
    let (off_ttft_p50, off_ttft_p95, off_ttft_p99) =
        sched_off.metrics.ttft_steps_pcts();
    let (off_itl_p50, off_itl_p95, off_itl_p99) =
        sched_off.metrics.itl_steps_pcts();
    println!(
        "chunked sched (budget {budget_tokens}): stall {} -> {} steps, \
         ttft p50/p95/p99 {:.1}/{:.1}/{:.1} -> {:.1}/{:.1}/{:.1} steps, \
         itl p50/p95/p99 {:.1}/{:.1}/{:.1} -> {:.1}/{:.1}/{:.1} steps \
         (drain {:.3}s -> {:.3}s)\n",
        sched_off.metrics.max_decode_stall_steps,
        sched_on.metrics.max_decode_stall_steps,
        off_ttft_p50,
        off_ttft_p95,
        off_ttft_p99,
        on_ttft_p50,
        on_ttft_p95,
        on_ttft_p99,
        off_itl_p50,
        off_itl_p95,
        off_itl_p99,
        on_itl_p50,
        on_itl_p95,
        on_itl_p99,
        sched_off_s,
        sched_on_s,
    );
    let bench = Json::obj(vec![
        ("bench", Json::Str("chunked_sched".into())),
        ("variant", Json::Str("fp".into())),
        ("step_token_budget", Json::Num(budget_tokens as f64)),
        (
            "max_decode_stall_steps_chunked",
            Json::Num(sched_on.metrics.max_decode_stall_steps as f64),
        ),
        (
            "max_decode_stall_steps_legacy",
            Json::Num(sched_off.metrics.max_decode_stall_steps as f64),
        ),
        ("ttft_steps_p50_chunked", Json::Num(on_ttft_p50)),
        ("ttft_steps_p95_chunked", Json::Num(on_ttft_p95)),
        ("ttft_steps_p99_chunked", Json::Num(on_ttft_p99)),
        ("ttft_steps_p50_legacy", Json::Num(off_ttft_p50)),
        ("ttft_steps_p95_legacy", Json::Num(off_ttft_p95)),
        ("ttft_steps_p99_legacy", Json::Num(off_ttft_p99)),
        ("itl_steps_p50_chunked", Json::Num(on_itl_p50)),
        ("itl_steps_p95_chunked", Json::Num(on_itl_p95)),
        ("itl_steps_p99_chunked", Json::Num(on_itl_p99)),
        ("itl_steps_p50_legacy", Json::Num(off_itl_p50)),
        ("itl_steps_p95_legacy", Json::Num(off_itl_p95)),
        ("itl_steps_p99_legacy", Json::Num(off_itl_p99)),
        (
            "engine_steps_chunked",
            Json::Num(sched_on.metrics.engine_steps as f64),
        ),
        (
            "engine_steps_legacy",
            Json::Num(sched_off.metrics.engine_steps as f64),
        ),
        ("drain_s_chunked", Json::Num(sched_on_s)),
        ("drain_s_legacy", Json::Num(sched_off_s)),
    ]);
    println!("BENCH {}", bench.emit());

    let bench = Json::obj(vec![
        ("bench", Json::Str("prefix_cache".into())),
        ("variant", Json::Str("fp".into())),
        ("prefix_hits", Json::Num(m_on.prefix_hits as f64)),
        (
            "prefill_tokens_skipped",
            Json::Num(m_on.prefill_tokens_skipped as f64),
        ),
        (
            "prefill_tokens",
            Json::Num(m_on.prefill_tokens as f64),
        ),
        ("cow_forks", Json::Num(m_on.cow_forks as f64)),
        (
            "shared_blocks_peak",
            Json::Num(m_on.shared_blocks as f64),
        ),
        (
            "kv_blocks_allocated_cache",
            Json::Num(m_on.kv_blocks_allocated as f64),
        ),
        (
            "kv_blocks_allocated_nocache",
            Json::Num(m_off.kv_blocks_allocated as f64),
        ),
        ("drain_s_cache", Json::Num(on_s)),
        ("drain_s_nocache", Json::Num(off_s)),
    ]);
    println!("BENCH {}", bench.emit());

    // ---- kernel-set sweep: tokens/sec through the FULL engine
    // (prefill + continuous-batched decode, paged KV, staged weights)
    // with each dispatch set pinned via EngineOptions::kernels.  The
    // streams must be bit-identical across sets — the dispatch layer's
    // whole contract — and the throughput rows land in the committed
    // BENCH_kernels.json trajectory next to the raw-GEMM GFLOP/s
    // section from `gemm_kernels`.
    let gen_tokens = if smoke { 6 } else { 16 };
    let mut kernel_records = Vec::new();
    let mut kernel_streams: Vec<Vec<Vec<i32>>> = Vec::new();
    for choice in
        [KernelChoice::Scalar, KernelChoice::Blocked, KernelChoice::Parallel]
    {
        let mut o = EngineOptions {
            variant: "w4a8_fast".into(),
            recipe: QuantRecipe::vanilla_w4(),
            max_queue: 16,
            ..Default::default()
        };
        o.paged = true;
        o.staging = true;
        o.kernels = choice;
        let mut engine = Engine::new(o).expect("engine");
        for i in 0..4u64 {
            engine.submit(Request::new(
                i,
                (0..24)
                    .map(|j| 3 + ((i as i32) * 7 + j) % 500)
                    .collect(),
                GenParams {
                    max_new_tokens: gen_tokens,
                    eos: None,
                    ..Default::default()
                },
            ));
        }
        let t0 = std::time::Instant::now();
        let mut results = engine.run_until_idle().expect("drain");
        let dt = t0.elapsed().as_secs_f64();
        results.sort_by_key(|r| r.id);
        let generated: usize =
            results.iter().map(|r| r.tokens.len()).sum();
        let tps = generated as f64 / dt.max(1e-9);
        let name = choice.name();
        println!(
            "{name:<10} engine: {generated} tokens in {dt:.3}s \
             = {tps:.1} tok/s"
        );
        kernel_streams
            .push(results.into_iter().map(|r| r.tokens).collect());
        kernel_records.push(Json::obj(vec![
            ("bench", Json::Str("hot_loop_kernels".into())),
            ("kernels", Json::Str(name.into())),
            ("variant", Json::Str("w4a8_fast".into())),
            ("tokens", Json::Num(generated as f64)),
            ("tokens_per_s", Json::Num(tps)),
            ("drain_s", Json::Num(dt)),
        ]));
    }
    for s in &kernel_streams[1..] {
        assert_eq!(
            &kernel_streams[0], s,
            "kernel sets must not change token streams"
        );
    }
    merge_bench_records(
        "BENCH_kernels.json",
        "hot_loop_kernels",
        &kernel_records,
    )
    .expect("write BENCH_kernels.json");
    for r in &kernel_records {
        println!("BENCH {}", r.emit());
    }

    // ---- parallel sampling: one n=4 request vs 4 independent copies
    // of the same sampled request (prefix cache OFF, so the ONLY
    // sharing is the prompt-KV fork).  The forked run must allocate
    // strictly fewer KV blocks — the prefill-once/fork-n acceptance
    // guard — and the record lands in the committed trajectory.
    let fork_prompt: Vec<i32> =
        (0..18).map(|i| 3 + (i * 13) % 500).collect();
    let run_fork = |n: usize, requests: u64| {
        let mut o = EngineOptions {
            variant: "fp".into(),
            recipe: QuantRecipe::vanilla_w4(),
            max_queue: 16,
            ..Default::default()
        };
        o.paged = true;
        o.staging = true;
        o.prefix_cache = false;
        o.kv_block_size = 4;
        let mut engine = Engine::new(o).expect("engine");
        for i in 0..requests {
            engine.submit(Request::new(
                i,
                fork_prompt.clone(),
                GenParams {
                    max_new_tokens: 8,
                    eos: None,
                    n,
                    temperature: 0.8,
                    seed: 7,
                    ..Default::default()
                },
            ));
        }
        let t0 = std::time::Instant::now();
        let results = engine.run_until_idle().expect("drain");
        let dt = t0.elapsed().as_secs_f64();
        let generated: usize = results
            .iter()
            .flat_map(|r| r.branches.iter())
            .map(|b| b.tokens.len())
            .sum();
        (generated, engine, dt)
    };
    let (forked_tokens, forked, forked_s) = run_fork(4, 1);
    let (indep_tokens, indep, indep_s) = run_fork(1, 4);
    assert_eq!(
        forked_tokens, indep_tokens,
        "both shapes generate 4 x 8 tokens"
    );
    let (m_fork, m_ind) = (&forked.metrics, &indep.metrics);
    assert_eq!(m_fork.forked_branches, 3);
    assert!(m_fork.cow_forks >= 3, "siblings must CoW-split the tail");
    assert!(
        m_fork.kv_blocks_allocated < m_ind.kv_blocks_allocated,
        "n=4 fork allocated {} KV blocks, 4 independent requests {} — \
         prompt sharing must allocate strictly fewer",
        m_fork.kv_blocks_allocated,
        m_ind.kv_blocks_allocated
    );
    println!(
        "parallel sampling: n=4 forked {} blocks vs independent {} \
         blocks ({} cow forks; drain {:.3}s vs {:.3}s)\n",
        m_fork.kv_blocks_allocated,
        m_ind.kv_blocks_allocated,
        m_fork.cow_forks,
        forked_s,
        indep_s,
    );
    let fork_records = vec![Json::obj(vec![
        ("bench", Json::Str("parallel_sampling".into())),
        ("variant", Json::Str("fp".into())),
        ("n", Json::Num(4.0)),
        (
            "kv_blocks_allocated_forked",
            Json::Num(m_fork.kv_blocks_allocated as f64),
        ),
        (
            "kv_blocks_allocated_independent",
            Json::Num(m_ind.kv_blocks_allocated as f64),
        ),
        ("cow_forks", Json::Num(m_fork.cow_forks as f64)),
        (
            "forked_branches",
            Json::Num(m_fork.forked_branches as f64),
        ),
        ("tokens", Json::Num(forked_tokens as f64)),
        ("drain_s_forked", Json::Num(forked_s)),
        ("drain_s_independent", Json::Num(indep_s)),
    ])];
    merge_bench_records(
        "BENCH_kernels.json",
        "parallel_sampling",
        &fork_records,
    )
    .expect("write BENCH_kernels.json");
    for r in &fork_records {
        println!("BENCH {}", r.emit());
    }

    // ---- quantized KV capacity: bytes-equal pools.  An int8 block
    // stores the same positions in 1/4 the arena bytes of an fp32
    // block (the per-(block, head) scales are noise next to the
    // payload), so at EQUAL arena bytes the int8 pool holds 4x the
    // blocks.  Run the tiny-pool overload from the preemption test
    // through both: the fp32 pool must thrash (preemptions fire), the
    // int8 pool at the same byte budget must preempt strictly less —
    // the capacity half of the quantized-KV story.  Token streams are
    // deliberately NOT compared across dtypes: int8 is lossy.
    let run_kv = |dtype: KvDtype, blocks: usize| {
        let mut o = EngineOptions {
            variant: "fp".into(),
            recipe: QuantRecipe::vanilla_w4(),
            max_queue: 32,
            ..Default::default()
        };
        o.paged = true;
        o.staging = true;
        o.prefix_cache = false;
        o.kv_block_size = 4;
        o.kv_blocks = Some(blocks);
        o.kv_quant = dtype;
        let mut engine = Engine::new(o).expect("engine");
        for i in 0..16u64 {
            let plen = 6 + (i as usize % 5);
            engine.submit(Request::new(
                i,
                (0..plen as i32)
                    .map(|j| 3 + ((i as i32) * 13 + j) % 500)
                    .collect(),
                GenParams {
                    max_new_tokens: 8 + (i as usize % 7),
                    eos: None,
                    ..Default::default()
                },
            ));
        }
        let t0 = std::time::Instant::now();
        let mut results = engine.run_until_idle().expect("drain");
        let dt = t0.elapsed().as_secs_f64();
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 16, "every request completes");
        for r in &results {
            assert_eq!(
                r.tokens.len(),
                8 + (r.id as usize % 7),
                "request {} got a truncated stream ({})",
                r.id,
                dtype.name()
            );
        }
        (engine, dt)
    };
    // fp32 gets the 12-block pool the preemption test proves too
    // small; int8 gets 12 x elem_bytes(fp32) = 48 blocks — the SAME
    // arena bytes, 4x the positions.
    let kv_blocks_f = 12usize;
    let kv_blocks_q = kv_blocks_f * KvDtype::F32.elem_bytes();
    let (kv_f, kv_f_s) = run_kv(KvDtype::F32, kv_blocks_f);
    let (kv_q, kv_q_s) = run_kv(KvDtype::Int8, kv_blocks_q);
    let (m_f, m_q) = (&kv_f.metrics, &kv_q.metrics);
    assert!(
        m_f.preempted >= 1,
        "the 12-block fp32 pool must force at least one preemption"
    );
    assert!(
        m_q.preempted < m_f.preempted,
        "int8 at equal arena bytes preempted {} times, fp32 {} — the \
         4x block budget must buy residency",
        m_q.preempted,
        m_f.preempted
    );
    assert_eq!(m_f.completed, 16);
    assert_eq!(m_q.completed, 16);
    println!(
        "kv quant capacity: fp32 {} blocks preempted {}x vs int8 {} \
         blocks (equal arena bytes) preempted {}x (blocks allocated \
         {} -> {}; drain {:.3}s -> {:.3}s)\n",
        kv_blocks_f,
        m_f.preempted,
        kv_blocks_q,
        m_q.preempted,
        m_f.kv_blocks_allocated,
        m_q.kv_blocks_allocated,
        kv_f_s,
        kv_q_s,
    );
    let kv_records = vec![Json::obj(vec![
        ("bench", Json::Str("kv_quant_capacity".into())),
        ("variant", Json::Str("fp".into())),
        ("blocks_fp32", Json::Num(kv_blocks_f as f64)),
        ("blocks_int8", Json::Num(kv_blocks_q as f64)),
        ("preempted_fp32", Json::Num(m_f.preempted as f64)),
        ("preempted_int8", Json::Num(m_q.preempted as f64)),
        (
            "kv_blocks_allocated_fp32",
            Json::Num(m_f.kv_blocks_allocated as f64),
        ),
        (
            "kv_blocks_allocated_int8",
            Json::Num(m_q.kv_blocks_allocated as f64),
        ),
        ("drain_s_fp32", Json::Num(kv_f_s)),
        ("drain_s_int8", Json::Num(kv_q_s)),
    ])];
    merge_bench_records(
        "BENCH_kernels.json",
        "kv_quant_capacity",
        &kv_records,
    )
    .expect("write BENCH_kernels.json");
    for r in &kv_records {
        println!("BENCH {}", r.emit());
    }

    // ---- speculative decoding: draft-k/verify-accept vs plain greedy
    // decode on the SAME traffic.  The draft checkpoint is distilled
    // from the target's bigram structure (runtime::synth), so greedy
    // acceptance should be high; the contract under test here is
    // (1) bit-identical token streams — speculative greedy emits
    // exactly what plain greedy would — and (2) the acceptance gauge
    // `accepted_tokens_per_target_step` > 1.0, i.e. each target verify
    // pass lands more than one token.  Wall-clock speedup is printed
    // (and recorded) but only soft-checked: the tiny synth model's
    // draft/target cost ratio is nothing like a real deployment's.
    let spec_k = 4usize;
    let spec_prompt_len = 20usize;
    let run_spec = |k: usize| {
        let mut o = EngineOptions {
            variant: "fp".into(),
            recipe: QuantRecipe::vanilla_w4(),
            max_queue: 16,
            ..Default::default()
        };
        o.paged = true;
        o.staging = true;
        o.speculative = k;
        let mut engine = Engine::new(o).expect("engine");
        for i in 0..4u64 {
            engine.submit(Request::new(
                i,
                (0..spec_prompt_len as i32)
                    .map(|j| 3 + ((i as i32) * 7 + j) % 500)
                    .collect(),
                GenParams {
                    max_new_tokens: gen_tokens,
                    eos: None,
                    ..Default::default()
                },
            ));
        }
        let t0 = std::time::Instant::now();
        let mut results = engine.run_until_idle().expect("drain");
        let dt = t0.elapsed().as_secs_f64();
        results.sort_by_key(|r| r.id);
        let tokens: Vec<Vec<i32>> =
            results.into_iter().map(|r| r.tokens).collect();
        (tokens, engine, dt)
    };
    let (spec_tokens, spec, spec_s) = run_spec(spec_k);
    let (plain_tokens, _plain, plain_s) = run_spec(0);
    assert_eq!(
        spec_tokens, plain_tokens,
        "speculative greedy must be bit-identical to plain greedy"
    );
    let m_spec = &spec.metrics;
    assert!(
        spec.speculative_active(),
        "draft model must have been staged"
    );
    assert!(
        m_spec.spec_steps > 0,
        "speculative run must execute verify passes"
    );
    let acc = m_spec.accepted_tokens_per_target_step();
    // soft guard: the bigram draft SHOULD land more than one token per
    // verify pass; a synth-model regression here is worth a loud line
    // but not a red bench (acceptance is a quality gauge, correctness
    // is the bit-identical assert above)
    if acc <= 1.0 {
        println!(
            "WARN speculative: draft accepted only {acc:.2} \
             tokens/target-step — no speedup over plain decode"
        );
    }
    let spec_tps = spec_tokens.iter().map(Vec::len).sum::<usize>() as f64
        / spec_s.max(1e-9);
    let plain_tps = plain_tokens.iter().map(Vec::len).sum::<usize>()
        as f64
        / plain_s.max(1e-9);
    println!(
        "speculative k={spec_k}: {} verify passes, {} proposed, {} \
         accepted, {} rollbacks, {acc:.2} tokens/target-step; \
         {spec_tps:.1} tok/s vs plain {plain_tps:.1} tok/s \
         (drain {spec_s:.3}s vs {plain_s:.3}s)\n",
        m_spec.spec_steps,
        m_spec.draft_tokens_proposed,
        m_spec.spec_accepted_tokens,
        m_spec.spec_rollbacks,
    );
    let spec_records = vec![Json::obj(vec![
        ("bench", Json::Str("speculative".into())),
        ("variant", Json::Str("fp".into())),
        ("draft_k", Json::Num(spec_k as f64)),
        ("spec_steps", Json::Num(m_spec.spec_steps as f64)),
        (
            "draft_tokens_proposed",
            Json::Num(m_spec.draft_tokens_proposed as f64),
        ),
        (
            "spec_accepted_tokens",
            Json::Num(m_spec.spec_accepted_tokens as f64),
        ),
        (
            "spec_rollbacks",
            Json::Num(m_spec.spec_rollbacks as f64),
        ),
        ("accepted_tokens_per_target_step", Json::Num(acc)),
        ("tokens_per_s_speculative", Json::Num(spec_tps)),
        ("tokens_per_s_plain", Json::Num(plain_tps)),
        ("drain_s_speculative", Json::Num(spec_s)),
        ("drain_s_plain", Json::Num(plain_s)),
    ])];
    merge_bench_records(
        "BENCH_kernels.json",
        "speculative",
        &spec_records,
    )
    .expect("write BENCH_kernels.json");
    for r in &spec_records {
        println!("BENCH {}", r.emit());
    }
}
