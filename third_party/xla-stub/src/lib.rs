//! Offline stub of the `xla` crate (PJRT bindings) API surface used by
//! the `pjrt` feature of this repository.
//!
//! The build environment cannot fetch the real `xla` crate (it needs a
//! network download plus a multi-GB XLA C++ toolchain), so this crate
//! keeps the `--features pjrt` code path COMPILING: every type and
//! signature the backend uses exists here, literal containers hold real
//! host data, and only the compile/execute entry points return a
//! "real PJRT runtime not linked" error at runtime.  Deployments with
//! the real toolchain replace this path dependency with the actual crate
//! (same API) via `[patch]` or by editing the workspace manifest.

use std::fmt;

/// Stub error type (mirrors `xla::Error` usage: `Debug` + `Display`).
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unlinked<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: this build uses the offline xla stub; link the real \
         xla/PJRT crate to execute AOT artifacts"
    )))
}

/// Element types of the artifacts this repo produces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S8,
    U8,
    S32,
    S64,
    U16,
}

impl ElementType {
    pub fn size(&self) -> usize {
        match self {
            ElementType::S8 | ElementType::U8 => 1,
            ElementType::U16 => 2,
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::F64 | ElementType::S64 => 8,
        }
    }
}

/// Maps rust scalar types onto [`ElementType`] for `Literal::to_vec`.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
}

macro_rules! native_impl {
    ($ty:ty, $tag:expr, $n:expr) => {
        impl NativeType for $ty {
            const TY: ElementType = $tag;
            fn from_le(bytes: &[u8]) -> Self {
                let mut b = [0u8; $n];
                b.copy_from_slice(bytes);
                <$ty>::from_le_bytes(b)
            }
        }
    };
}

native_impl!(f32, ElementType::F32, 4);
native_impl!(f64, ElementType::F64, 8);
native_impl!(i8, ElementType::S8, 1);
native_impl!(u8, ElementType::U8, 1);
native_impl!(i32, ElementType::S32, 4);
native_impl!(i64, ElementType::S64, 8);
native_impl!(u16, ElementType::U16, 2);

/// Host literal: shape + element type + raw little-endian bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    pub ty: ElementType,
    pub shape: Vec<usize>,
    pub bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = shape.iter().product();
        if numel * ty.size() != data.len() {
            return Err(Error(format!(
                "literal: shape {shape:?} x {ty:?} wants {} bytes, got {}",
                numel * ty.size(),
                data.len()
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), bytes: data.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error(format!(
                "literal holds {:?}, asked for {:?}",
                self.ty,
                T::TY
            )));
        }
        let n = T::TY.size();
        Ok(self.bytes.chunks_exact(n).map(T::from_le).collect())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unlinked("Literal::to_tuple")
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unlinked("Literal::to_tuple1")
    }
}

/// npy loading half of the real crate's `FromRawBytes` trait.
pub trait FromRawBytes: Sized {
    type Context;
    fn read_npy<P: AsRef<std::path::Path>>(
        path: P,
        ctx: &Self::Context,
    ) -> Result<Self>;
}

impl FromRawBytes for Literal {
    type Context = ();

    fn read_npy<P: AsRef<std::path::Path>>(
        _path: P,
        _ctx: &Self::Context,
    ) -> Result<Self> {
        unlinked("Literal::read_npy")
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unlinked("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Device buffer handle (opaque in the stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unlinked("PjRtBuffer::to_literal_sync")
    }
}

/// Argument kinds accepted by `PjRtLoadedExecutable::execute*`.
pub trait ExecuteArg {}
impl ExecuteArg for Literal {}
impl ExecuteArg for &Literal {}
impl ExecuteArg for &PjRtBuffer {}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: ExecuteArg>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unlinked("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<L: ExecuteArg>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unlinked("PjRtLoadedExecutable::execute_b")
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unlinked("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unlinked("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        unlinked("PjRtClient::buffer_from_host_literal")
    }
}
