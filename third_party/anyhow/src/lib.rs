//! Minimal, dependency-free reimplementation of the subset of the
//! `anyhow` API this workspace uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension trait.
//!
//! Vendored so `cargo build` works fully offline (the build environment
//! has no crates.io access).  The surface is intentionally tiny; if the
//! real crate becomes available, deleting this directory and pointing
//! Cargo at the registry is a drop-in swap.

use std::fmt;

/// A string-chain error: a message plus an optional cause.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole cause chain, like anyhow
            self.write_chain(f)
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(first) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = Some(first);
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes this blanket conversion (and hence `?` on io/parse errors)
// coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut msgs: Vec<String> = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            out = Some(Error { msg: m, source: out.map(Box::new) });
        }
        out.expect("at least one message")
    }
}

/// `anyhow::Result<T>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result`/`Option` errors.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (captures inline args).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("inner {}", 42))
    }

    #[test]
    fn chain_formats() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn io_fail() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(io_fail().is_err());
    }

    #[test]
    fn bail_returns() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged");
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert!(f(true).is_err());
    }
}
