"""L2 — LLaMA-architecture forward passes, parameterized by GEMM variant.

Two entry points are AOT-lowered per (model, variant, batch-bucket):

  * prefill : tokens[B,S], length[B]  -> logits[B,S,V], per-layer KV caches
  * decode  : token[B], pos[B], KV    -> logits[B,V],   updated KV caches

Weights are *arguments* (a flat list in the canonical configs.weight_names
order, with quantized matrices expanded into their payload tensors), so the
same compiled executable serves any checkpoint — the rust coordinator owns
the weights, the graph owns only the math.

Every linear runs through the L1 Pallas kernel of the chosen variant
(`use_ref=True` swaps in the pure-jnp oracles for testing).  Activations
are quantized per token ONCE per "linear group" (q/k/v share one input,
gate/up share one input) — the fusion the paper's engine applies.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import configs
from .configs import ModelConfig
from .kernels import (asym, fastgemm, finegrained, fpgemm, ref, w4a16, w8a8)


# --------------------------------------------------------------------------
# variant payload specs
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class VariantSpec:
    """How a quantized matrix is represented and applied."""
    name: str
    payload: tuple            # payload tensor suffixes, in argument order
    quant_act: bool           # whether x is per-token int8-quantized

    def payload_names(self, base: str):
        return [f"{base}.{p}" for p in self.payload]


SPECS = {
    "fp": VariantSpec("fp", ("w",), False),
    "w8a8": VariantSpec("w8a8", ("wq", "s_w"), True),
    "w4a8_fast": VariantSpec("w4a8_fast", ("wp", "s_w"), True),
    "w4a8_group": VariantSpec("w4a8_group", ("wq", "s_g"), True),
    "w4a8_asym": VariantSpec("w4a8_asym", ("wu", "s_w", "z"), True),
    "w4a16": VariantSpec("w4a16", ("wq", "s_g"), False),
}


def payload_shapes(variant: str, k: int, n: int, group: int):
    """Shapes+dtypes of the payload tensors for a KxN matrix."""
    g = k // group
    return {
        "fp": [((k, n), jnp.float32)],
        "w8a8": [((k, n), jnp.int8), ((n,), jnp.float32)],
        "w4a8_fast": [((k // 2, n), jnp.uint8), ((n,), jnp.float32)],
        "w4a8_group": [((k, n), jnp.int8), ((g, n), jnp.float32)],
        "w4a8_asym": [((k, n), jnp.uint8), ((n,), jnp.float32),
                      ((n,), jnp.int32)],
        "w4a16": [((k, n), jnp.int8), ((g, n), jnp.float32)],
    }[variant]


def quantize_matrix(variant: str, w, group: int):
    """Reference payload construction from an f32[K,N] matrix (RTN only —
    the full LWC/GPTQ pipeline lives in quant.py / rust quant::)."""
    w = jnp.asarray(w)
    if variant == "fp":
        return [w]
    if variant == "w8a8":
        q, s = ref.quant_weight_per_channel_sym(w, 8)
        return [q, s]
    if variant == "w4a8_fast":
        q, s = ref.quant_weight_per_channel_sym(w, 4)
        return [ref.pack_int4(q), s]
    if variant in ("w4a8_group", "w4a16"):
        q, s = ref.quant_weight_per_group_sym(w, group, 4)
        return [q, s]
    if variant == "w4a8_asym":
        u, s, z = ref.quant_weight_per_channel_asym(w, 4)
        return [u, s, z]
    raise ValueError(variant)


def _apply(variant: str, xq_or_x, s_a, payload, group: int, use_ref: bool):
    """Run one GEMM given the (possibly pre-quantized) input."""
    if variant == "fp":
        f = ref.gemm_fp if use_ref else fpgemm.gemm_fp
        return f(xq_or_x, payload[0])
    if variant == "w8a8":
        f = ref.gemm_w8a8 if use_ref else w8a8.gemm_w8a8
        return f(xq_or_x, s_a, payload[0], payload[1])
    if variant == "w4a8_fast":
        f = ref.gemm_w4a8_fast if use_ref else fastgemm.gemm_w4a8_fast
        return f(xq_or_x, s_a, payload[0], payload[1])
    if variant == "w4a8_group":
        f = (ref.gemm_w4a8_grouped if use_ref
             else finegrained.gemm_w4a8_grouped)
        return f(xq_or_x, s_a, payload[0], payload[1], group)
    if variant == "w4a8_asym":
        f = ref.gemm_w4a8_asym if use_ref else asym.gemm_w4a8_asym
        return f(xq_or_x, s_a, payload[0], payload[1], payload[2])
    if variant == "w4a16":
        f = ref.gemm_w4a16 if use_ref else w4a16.gemm_w4a16
        return f(xq_or_x, payload[0], payload[1], group)
    raise ValueError(variant)


class LinearGroup:
    """Applies several matrices to ONE input, quantizing the input once."""

    def __init__(self, variant: str, group: int, use_ref: bool):
        self.spec = SPECS[variant]
        self.variant = variant
        self.group = group
        self.use_ref = use_ref

    def __call__(self, x2d, payloads):
        """x2d: f32[M,K]; payloads: list of payload lists -> [f32[M,N]]."""
        if self.spec.quant_act:
            xq, s_a = ref.quant_act_per_token(x2d)
        else:
            xq, s_a = x2d, None
        return [_apply(self.variant, xq, s_a, p, self.group, self.use_ref)
                for p in payloads]


# --------------------------------------------------------------------------
# LLaMA blocks
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope_tables(cfg: ModelConfig, positions):
    """positions: i32[...]; returns (cos, sin) of shape [..., head_dim//2]."""
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., H, Dh]; cos/sin broadcastable to [..., 1, Dh//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def swiglu(gate, up):
    return jax.nn.silu(gate) * up


NEG_INF = -1e9


# --------------------------------------------------------------------------
# weights handling
# --------------------------------------------------------------------------

def init_weights(cfg: ModelConfig, seed: int = 0):
    """f32 initialization (dict name -> np.ndarray), canonical order."""
    rng = np.random.default_rng(seed)
    ws = {}
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab

    def mat(k, n):
        return (rng.standard_normal((k, n)) / np.sqrt(k)).astype(np.float32)

    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        ws[p + "attn_norm"] = np.ones(d, np.float32)
        for nm in ("wq", "wk", "wv", "wo"):
            ws[p + nm] = mat(d, d)
        ws[p + "mlp_norm"] = np.ones(d, np.float32)
        ws[p + "w_gate"] = mat(d, f)
        ws[p + "w_up"] = mat(d, f)
        ws[p + "w_down"] = mat(f, d)
    ws["norm_f"] = np.ones(d, np.float32)
    ws["embed"] = (rng.standard_normal((v, d)) * 0.02).astype(np.float32)
    ws["lm_head"] = mat(d, v)
    return ws


def quantize_weights(cfg: ModelConfig, ws, variant: str,
                     group: int = configs.GROUP_SIZE):
    """dict of f32 weights -> flat payload list in canonical arg order."""
    flat = []
    for name in configs.weight_names(cfg):
        leaf = name.split(".")[-1]
        if leaf in configs.LAYER_MATRICES:
            flat.extend(quantize_matrix(variant, ws[name], group))
        else:
            flat.append(jnp.asarray(ws[name]))
    return flat


def flat_param_entries(cfg: ModelConfig, variant: str,
                       group: int = configs.GROUP_SIZE):
    """(name, shape, dtype) for every flat weight argument — the manifest."""
    out = []
    for name in configs.weight_names(cfg):
        leaf = name.split(".")[-1]
        if leaf in configs.LAYER_MATRICES:
            k, n = configs.matrix_shape(cfg, name)
            spec = SPECS[variant]
            shapes = payload_shapes(variant, k, n, group)
            for suffix, (shape, dt) in zip(spec.payload, shapes):
                out.append((f"{name}.{suffix}", shape, dt))
        elif leaf in ("attn_norm", "mlp_norm", "norm_f"):
            out.append((name, (cfg.d_model,), jnp.float32))
        else:  # embed / lm_head stay f32
            out.append((name, configs.matrix_shape(cfg, name), jnp.float32))
    return out


class WeightCursor:
    """Walks the flat weight-argument list in canonical order."""

    def __init__(self, cfg: ModelConfig, variant: str, flat):
        self.cfg = cfg
        self.spec = SPECS[variant]
        self.flat = list(flat)
        self.i = 0

    def take(self):
        out = self.flat[self.i]
        self.i += 1
        return out

    def matrix(self):
        """Take one quantized-matrix payload (list of tensors)."""
        n = len(self.spec.payload)
        out = self.flat[self.i:self.i + n]
        self.i += n
        return out


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------

def _layer_prefill(cfg, lin, cur, x, cos, sin, mask, taps):
    """One decoder layer over x: f32[B,S,D].  Returns (x, kT, vT)."""
    B, S, D = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    attn_norm = cur.take()
    wq, wk, wv, wo = cur.matrix(), cur.matrix(), cur.matrix(), cur.matrix()
    mlp_norm = cur.take()
    w_gate, w_up, w_down = cur.matrix(), cur.matrix(), cur.matrix()

    h = rms_norm(x, attn_norm, cfg.norm_eps)
    h2 = h.reshape(B * S, D)
    if taps is not None:
        taps.append(("attn_in", h2))
    q, k, v = lin(h2, [wq, wk, wv])
    q = apply_rope(q.reshape(B, S, H, Dh), cos, sin)
    k = apply_rope(k.reshape(B, S, H, Dh), cos, sin)
    v = v.reshape(B, S, H, Dh)
    qT = q.transpose(0, 2, 1, 3)          # [B,H,S,Dh]
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qT, kT) / np.sqrt(Dh)
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    att = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", att, vT).transpose(0, 2, 1, 3)
    o2 = o.reshape(B * S, D)
    if taps is not None:
        taps.append(("attn_out_in", o2))
    (o_proj,) = lin(o2, [wo])
    x = x + o_proj.reshape(B, S, D)

    h = rms_norm(x, mlp_norm, cfg.norm_eps)
    h2 = h.reshape(B * S, D)
    if taps is not None:
        taps.append(("mlp_in", h2))
    gate, up = lin(h2, [w_gate, w_up])
    act = swiglu(gate, up)
    if taps is not None:
        taps.append(("mlp_down_in", act))
    (down,) = lin(act, [w_down])
    x = x + down.reshape(B, S, D)
    return x, kT, vT


def prefill(cfg: ModelConfig, variant: str, tokens, length, *flat_weights,
            group: int = configs.GROUP_SIZE, use_ref: bool = False,
            collect_taps: bool = False):
    """tokens: i32[B,S], length: i32[B].

    Returns (logits[B,S,V] f32, *k_caches, *v_caches) with caches padded to
    cfg.max_seq: each [B,H,max_seq,Dh].
    """
    B, S = tokens.shape
    lin = LinearGroup(variant, group, use_ref)
    cur = WeightCursor(cfg, variant, flat_weights)
    taps = [] if collect_taps else None

    positions = jnp.arange(S)[None, :].repeat(B, 0)          # [B,S]
    cos, sin = rope_tables(cfg, positions)                   # [B,S,Dh/2]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    idx = jnp.arange(S)
    causal = idx[None, :] <= idx[:, None]                    # [S,S]
    keymask = idx[None, None, :] < length[:, None, None]     # [B,1,S]
    mask = causal[None, :, :] & keymask                      # [B,S,S]

    embed = flat_weights[-2]                                 # canonical tail
    x = jnp.take(embed, tokens, axis=0)                      # [B,S,D]

    ks, vs = [], []
    for _ in range(cfg.n_layers):
        x, kT, vT = _layer_prefill(cfg, lin, cur, x, cos, sin, mask, taps)
        pad = cfg.max_seq - S
        ks.append(jnp.pad(kT, ((0, 0), (0, 0), (0, pad), (0, 0))))
        vs.append(jnp.pad(vT, ((0, 0), (0, 0), (0, pad), (0, 0))))
    norm_f = cur.take()
    x = rms_norm(x, norm_f, cfg.norm_eps)
    x2 = x.reshape(B * S, cfg.d_model)
    if taps is not None:
        taps.append(("lm_head_in", x2))
    _embed = cur.take()          # keeps the cursor aligned with the layout
    lm_head = cur.take()
    logits = ref.gemm_fp(x2, lm_head).reshape(B, S, cfg.vocab)
    if collect_taps:
        return (logits, ks, vs), taps
    return (logits, *ks, *vs)


def _layer_decode(cfg, lin, cur, x, pos, cos, sin, kc, vc):
    """x: f32[B,D]; kc/vc: [B,H,Smax,Dh].  Returns (x, kc, vc)."""
    B, D = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    attn_norm = cur.take()
    wq, wk, wv, wo = cur.matrix(), cur.matrix(), cur.matrix(), cur.matrix()
    mlp_norm = cur.take()
    w_gate, w_up, w_down = cur.matrix(), cur.matrix(), cur.matrix()

    h = rms_norm(x, attn_norm, cfg.norm_eps)
    q, k, v = lin(h, [wq, wk, wv])
    q = apply_rope(q.reshape(B, H, Dh), cos, sin)
    k = apply_rope(k.reshape(B, H, Dh), cos, sin)
    v = v.reshape(B, H, Dh)

    # write k,v at pos — per batch element (continuous batching).
    def upd(cache, val, p):
        return jax.lax.dynamic_update_slice(
            cache, val[:, None, :], (0, p, 0))
    kc = jax.vmap(upd)(kc, k, pos)
    vc = jax.vmap(upd)(vc, v, pos)

    scores = jnp.einsum("bhd,bhkd->bhk", q, kc) / np.sqrt(Dh)
    k_idx = jnp.arange(kc.shape[2])[None, None, :]
    mask = k_idx <= pos[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    att = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhk,bhkd->bhd", att, vc).reshape(B, D)
    (o_proj,) = lin(o, [wo])
    x = x + o_proj

    h = rms_norm(x, mlp_norm, cfg.norm_eps)
    gate, up = lin(h, [w_gate, w_up])
    (down,) = lin(swiglu(gate, up), [w_down])
    return x + down, kc, vc


def decode(cfg: ModelConfig, variant: str, token, pos, *rest,
           group: int = configs.GROUP_SIZE, use_ref: bool = False):
    """token: i32[B], pos: i32[B], rest = n_layers k-caches, n_layers
    v-caches, then the flat weights.

    Returns (logits[B,V], *new_k_caches, *new_v_caches).
    """
    L = cfg.n_layers
    kcs = list(rest[:L])
    vcs = list(rest[L:2 * L])
    flat_weights = rest[2 * L:]
    lin = LinearGroup(variant, group, use_ref)
    cur = WeightCursor(cfg, variant, flat_weights)

    cos, sin = rope_tables(cfg, pos)                      # [B,Dh/2]
    cos, sin = cos[:, None, :], sin[:, None, :]           # [B,1,Dh/2]
    embed = flat_weights[-2]
    x = jnp.take(embed, token, axis=0)                    # [B,D]

    new_k, new_v = [], []
    for i in range(L):
        x, kc, vc = _layer_decode(cfg, lin, cur, x, pos, cos, sin,
                                  kcs[i], vcs[i])
        new_k.append(kc)
        new_v.append(vc)
    norm_f = cur.take()
    x = rms_norm(x, norm_f, cfg.norm_eps)
    _embed = cur.take()
    lm_head = cur.take()
    logits = ref.gemm_fp(x, lm_head)
    return (logits, *new_k, *new_v)


# --------------------------------------------------------------------------
# jit'able builders (fixed model/variant/bucket)
# --------------------------------------------------------------------------

def make_prefill(cfg, variant, use_ref=False, group=configs.GROUP_SIZE):
    return functools.partial(prefill, cfg, variant, group=group,
                             use_ref=use_ref)


def make_decode(cfg, variant, use_ref=False, group=configs.GROUP_SIZE):
    return functools.partial(decode, cfg, variant, group=group,
                             use_ref=use_ref)


def kv_shapes(cfg: ModelConfig, batch: int):
    """Shapes of the 2*n_layers KV cache arguments."""
    s = (batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    return [s] * (2 * cfg.n_layers)
