"""Build-time compile package: L1 kernels, L2 model, quantization
reference, AOT export.  Never imported at runtime by the rust engine."""
