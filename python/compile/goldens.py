"""Golden-file emitter: python-reference results the rust unit tests
replay bit-for-bit (ints) / to 1e-5 (floats).

Everything is derived from fixed seeds so `make artifacts` is
deterministic.  Output: artifacts/goldens.safetensors.
"""

import os

import numpy as np
import jax.numpy as jnp

from . import quant, stio
from .kernels import ref


def build_goldens(seed: int = 42) -> dict:
    rng = np.random.default_rng(seed)
    g = {}

    K, N = 32, 16
    w = rng.normal(size=(K, N)).astype(np.float32)
    x = rng.normal(size=(64, K)).astype(np.float32)
    # inject a few outlier channels like real LLM activations
    x[:, 3] *= 8.0
    x[:, 17] *= 5.0
    g["in.w"] = w
    g["in.x"] = x

    # RTN per-channel 4/8 bit
    for bits in (4, 8):
        q, s = quant.rtn_per_channel(w, bits)
        g[f"rtn_pc{bits}.q"] = q
        g[f"rtn_pc{bits}.s"] = s
    # RTN per-group
    qg, sg = quant.rtn_per_group(w, 8, 4)
    g["rtn_g8.q"] = qg
    g["rtn_g8.s"] = sg

    # LWC grid search
    gamma, beta = quant.lwc_grid_search(w, 4)
    g["lwc.gamma"] = gamma
    g["lwc.beta"] = beta
    qlwc, slwc = quant.rtn_per_channel(w, 4, gamma, beta)
    g["lwc.q"] = qlwc
    g["lwc.s"] = slwc

    # GPTQ (pc scales fixed by LWC) and GPTQ-ro
    H = (2.0 * x.T @ x / x.shape[0]).astype(np.float32)
    g["in.h"] = H
    qq, qs, _ = quant.gptq_quantize(w, H, 4, scale=slwc)
    g["gptq.q"] = qq
    g["gptq.s"] = qs
    qr, rs, perm = quant.gptq_quantize(w, H, 4, act_order=True)
    g["gptq_ro.q"] = qr
    g["gptq_ro.s"] = rs
    g["gptq_ro.perm"] = perm.astype(np.int64)
    qgrp, sgrp, _ = quant.gptq_quantize(w, H, 4, group=8)
    g["gptq_g8.q"] = qgrp
    g["gptq_g8.s"] = sgrp

    # packing
    p = np.asarray(ref.pack_int4(jnp.asarray(qlwc)))
    g["pack.p"] = p
    g["pack.unpacked_x16"] = np.asarray(ref.unpack_int4_x16(jnp.asarray(p)))

    # SmoothQuant / AWQ scales
    absmax = np.abs(x).max(axis=0).astype(np.float32)
    absmean = np.abs(x).mean(axis=0).astype(np.float32)
    g["in.absmax"] = absmax
    g["in.absmean"] = absmean
    g["sq.scales"] = quant.smoothquant_scales(absmax, w, 0.5)
    g["awq.scales"] = quant.awq_scales(absmean, w, x, bits=4, group=8)

    # activation quant
    xq, s_a = ref.quant_act_per_token(jnp.asarray(x[:8]))
    g["actq.q"] = np.asarray(xq)
    g["actq.s"] = np.asarray(s_a)

    # GEMM I/O per variant (M=8)
    xs = jnp.asarray(x[:8])
    xq8, sa8 = ref.quant_act_per_token(xs)
    q8, s8 = quant.rtn_per_channel(w, 8)
    g["gemm_w8a8.out"] = np.asarray(
        ref.gemm_w8a8(xq8, sa8, jnp.asarray(q8), jnp.asarray(s8)))
    g["gemm_fast.out"] = np.asarray(
        ref.gemm_w4a8_fast(xq8, sa8, jnp.asarray(p), jnp.asarray(slwc)))
    g["gemm_group.out"] = np.asarray(
        ref.gemm_w4a8_grouped(xq8, sa8, jnp.asarray(qg), jnp.asarray(sg), 8))
    uu, us, uz = ref.quant_weight_per_channel_asym(jnp.asarray(w), 4)
    g["asym.u"] = np.asarray(uu)
    g["asym.s"] = np.asarray(us)
    g["asym.z"] = np.asarray(uz)
    g["gemm_asym.out"] = np.asarray(ref.gemm_w4a8_asym(xq8, sa8, uu, us, uz))
    g["gemm_w4a16.out"] = np.asarray(
        ref.gemm_w4a16(xs, jnp.asarray(qg), jnp.asarray(sg), 8))
    g["gemm_fp.out"] = np.asarray(ref.gemm_fp(xs, jnp.asarray(w)))
    return g


def save(outdir: str):
    os.makedirs(outdir, exist_ok=True)
    stio.save(os.path.join(outdir, "goldens.safetensors"), build_goldens())
