"""AOT driver: the ONE-TIME python pass producing everything in artifacts/.

    python -m compile.aot --outdir ../artifacts

Steps (each skipped when its outputs already exist, so `make artifacts`
is an incremental no-op):

  1. synthetic corpus + eval tasks            (data.py)
  2. tiny-llama checkpoints, trained on 1.    (train.py)
  3. calibration hessians + act stats         (calib.py)
  4. rust cross-check goldens                 (goldens.py)
  5. HLO text graphs: prefill/decode per (model, variant, batch bucket)
     plus standalone GEMM kernel graphs       (model.py, kernels/*)
  6. manifest.json describing every graph's parameter/output interface

Interchange is HLO TEXT (see hlo.py for why).  After this script runs the
rust binary is fully self-contained.
"""

import argparse
import json
import os

import numpy as np

import jax
import jax.numpy as jnp

from . import calib, configs, data, goldens, hlo, model, stio, train
from .configs import ModelConfig

DT = {"float32": "f32", "int8": "s8", "uint8": "u8", "int32": "s32"}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _entry(kind, path, params, outputs, **meta):
    e = {"kind": kind, "path": os.path.basename(path),
         "params": params, "outputs": outputs}
    e.update(meta)
    return e


def _param_list(names_shapes_dtypes):
    return [{"name": n, "shape": [int(x) for x in s],
             "dtype": DT[str(np.dtype(d))]}
            for (n, s, d) in names_shapes_dtypes]


def export_model_graphs(cfg: ModelConfig, variants, prefill_batches,
                        decode_batches, outdir, manifest):
    S = configs.PREFILL_SEQ
    for variant in variants:
        wents = model.flat_param_entries(cfg, variant)
        w_sds = [_sds(s, d) for (_n, s, d) in wents]
        for B in prefill_batches:
            name = f"{cfg.name}_{variant}_prefill_b{B}"
            path = os.path.join(outdir, f"{name}.hlo.txt")
            if not os.path.exists(path):
                fn = model.make_prefill(cfg, variant)
                args = (_sds((B, S), jnp.int32), _sds((B,), jnp.int32),
                        *w_sds)
                hlo.export(fn, args, path)
                print(f"  lowered {name}", flush=True)
            params = _param_list(
                [("tokens", (B, S), np.int32), ("length", (B,), np.int32)]
                + wents)
            outs = [{"name": "logits", "shape": [B, S, cfg.vocab],
                     "dtype": "f32"}]
            for pfx in ("k_cache", "v_cache"):
                for i in range(cfg.n_layers):
                    outs.append({"name": f"{pfx}.{i}",
                                 "shape": [B, cfg.n_heads, cfg.max_seq,
                                           cfg.head_dim], "dtype": "f32"})
            manifest["graphs"][name] = _entry(
                "prefill", path, params, outs, model=cfg.name,
                variant=variant, batch=B, seq=S)
        for B in decode_batches:
            name = f"{cfg.name}_{variant}_decode_b{B}"
            path = os.path.join(outdir, f"{name}.hlo.txt")
            kv = [_sds(s, jnp.float32) for s in model.kv_shapes(cfg, B)]
            if not os.path.exists(path):
                fn = model.make_decode(cfg, variant)
                args = (_sds((B,), jnp.int32), _sds((B,), jnp.int32),
                        *kv, *w_sds)
                hlo.export(fn, args, path)
                print(f"  lowered {name}", flush=True)
            kv_params = \
                [(f"k_cache.{i}", kv[i].shape, np.float32)
                 for i in range(cfg.n_layers)] + \
                [(f"v_cache.{i}", kv[i].shape, np.float32)
                 for i in range(cfg.n_layers)]
            params = _param_list(
                [("token", (B,), np.int32), ("pos", (B,), np.int32)]
                + kv_params + wents)
            outs = [{"name": "logits", "shape": [B, cfg.vocab],
                     "dtype": "f32"}]
            for pfx in ("k_cache", "v_cache"):
                for i in range(cfg.n_layers):
                    outs.append({"name": f"{pfx}.{i}",
                                 "shape": list(kv[i].shape),
                                 "dtype": "f32"})
            manifest["graphs"][name] = _entry(
                "decode", path, params, outs, model=cfg.name,
                variant=variant, batch=B, seq=cfg.max_seq)


def _gemm_sig(variant, M, N, K, group):
    g = max(K // group, 1)
    if variant == "fp":
        return [("x", (M, K), np.float32), ("w", (K, N), np.float32)]
    if variant == "w8a8":
        return [("xq", (M, K), np.int8), ("s_a", (M,), np.float32),
                ("wq", (K, N), np.int8), ("s_w", (N,), np.float32)]
    if variant in ("w4a8_fast", "w4a8_unfused"):
        return [("xq", (M, K), np.int8), ("s_a", (M,), np.float32),
                ("wp", (K // 2, N), np.uint8), ("s_w", (N,), np.float32)]
    if variant == "w4a8_group":
        return [("xq", (M, K), np.int8), ("s_a", (M,), np.float32),
                ("wq", (K, N), np.int8), ("s_g", (g, N), np.float32)]
    if variant == "w4a8_asym":
        return [("xq", (M, K), np.int8), ("s_a", (M,), np.float32),
                ("wu", (K, N), np.uint8), ("s_w", (N,), np.float32),
                ("z", (N,), np.int32)]
    if variant == "w4a16":
        return [("x", (M, K), np.float32), ("wq", (K, N), np.int8),
                ("s_g", (g, N), np.float32)]
    raise ValueError(variant)


def _gemm_fn(variant, group):
    from .kernels import (asym, fastgemm, finegrained, fpgemm, w4a16, w8a8)
    if variant == "fp":
        return lambda x, w: (fpgemm.gemm_fp(x, w),)
    if variant == "w8a8":
        return lambda xq, sa, wq, sw: (w8a8.gemm_w8a8(xq, sa, wq, sw),)
    if variant == "w4a8_fast":
        return lambda xq, sa, wp, sw: (
            fastgemm.gemm_w4a8_fast(xq, sa, wp, sw),)
    if variant == "w4a8_unfused":
        return lambda xq, sa, wp, sw: (
            fpgemm.gemm_w4a8_unfused(xq, sa, wp, sw),)
    if variant == "w4a8_group":
        return lambda xq, sa, wq, sg: (
            finegrained.gemm_w4a8_grouped(xq, sa, wq, sg, group),)
    if variant == "w4a8_asym":
        return lambda xq, sa, wu, sw, z: (
            asym.gemm_w4a8_asym(xq, sa, wu, sw, z),)
    if variant == "w4a16":
        return lambda x, wq, sg: (w4a16.gemm_w4a16(x, wq, sg, group),)
    raise ValueError(variant)


# which variants get standalone GEMM graphs per shape set
GEMM_EXPORTS = {
    # fig7 / tab5 measured: the three W4A8 paradigms + baselines at the
    # paper's LLaMA-2-70B TP4 shapes
    "paper": ("fp", "w8a8", "w4a8_fast", "w4a8_group", "w4a8_asym",
              "w4a16"),
    # fusion ablation (Fig. 4 b vs c) + quick benches at CPU-scaled shapes
    "cpu": ("fp", "w8a8", "w4a8_fast", "w4a8_unfused", "w4a8_group",
            "w4a8_asym", "w4a16"),
}


def export_gemm_graphs(outdir, manifest):
    sets = {
        "paper": (configs.PAPER_GEMM_NK, 128),
        "cpu": (configs.CPU_GEMM_NK, configs.GROUP_SIZE),
    }
    for set_name, (nks, group) in sets.items():
        for variant in GEMM_EXPORTS[set_name]:
            for (N, K) in nks:
                for M in configs.PAPER_GEMM_MS:
                    name = f"gemm_{variant}_{set_name}_m{M}n{N}k{K}"
                    path = os.path.join(outdir, f"{name}.hlo.txt")
                    sig = _gemm_sig(variant, M, N, K, group)
                    if not os.path.exists(path):
                        fn = _gemm_fn(variant, group)
                        args = tuple(_sds(s, d) for (_n, s, d) in sig)
                        hlo.export(fn, args, path)
                        print(f"  lowered {name}", flush=True)
                    manifest["graphs"][name] = _entry(
                        "gemm", path, _param_list(sig),
                        [{"name": "out", "shape": [M, N], "dtype": "f32"}],
                        variant=variant, m=M, n=N, k=K, group=group,
                        shape_set=set_name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=700)
    ap.add_argument("--steps9m", type=int, default=350)
    ap.add_argument("--skip-9m", action="store_true")
    args = ap.parse_args()
    outdir = args.outdir
    os.makedirs(outdir, exist_ok=True)

    # 1. corpus + tasks ----------------------------------------------------
    if not os.path.exists(os.path.join(outdir, "tasks.json")):
        print("[aot] generating synthetic corpus + tasks", flush=True)
        data.write_all(outdir)
    train_tok = np.fromfile(os.path.join(outdir, "corpus_train.bin"),
                            dtype=np.uint16)
    val_tok = np.fromfile(os.path.join(outdir, "corpus_val.bin"),
                          dtype=np.uint16)

    # 2. train checkpoints ---------------------------------------------------
    model_list = ["tiny3m"] + ([] if args.skip_9m else ["tiny9m"])
    for mname in model_list:
        cfg = configs.MODELS[mname]
        ck = os.path.join(outdir, f"{cfg.name}.safetensors")
        if not os.path.exists(ck):
            steps = args.steps if mname == "tiny3m" else args.steps9m
            print(f"[aot] training {mname} ({cfg.n_params()/1e6:.1f}M "
                  f"params, {steps} steps)", flush=True)
            train.train(cfg, train_tok, val_tok, steps=steps, outdir=outdir)

    # 3. calibration ---------------------------------------------------------
    for mname in model_list:
        cfg = configs.MODELS[mname]
        hp = os.path.join(outdir, f"hessians_{cfg.name}.safetensors")
        if not os.path.exists(hp):
            print(f"[aot] calibrating {mname} (128 seqs)", flush=True)
            ws = {k: jnp.asarray(v) for k, v in stio.load(
                os.path.join(outdir, f"{cfg.name}.safetensors")).items()}
            ct = calib.calib_sequences(train_tok)
            stats = calib.run_calibration(cfg, ws, ct)
            calib.save_calibration(cfg, stats, outdir)

    # 4. goldens --------------------------------------------------------------
    if not os.path.exists(os.path.join(outdir, "goldens.safetensors")):
        print("[aot] emitting rust cross-check goldens", flush=True)
        goldens.save(outdir)

    # 5./6. HLO graphs + manifest ---------------------------------------------
    manifest = {"group_size": configs.GROUP_SIZE, "graphs": {},
                "models": {}}
    for mname in model_list:
        cfg = configs.MODELS[mname]
        manifest["models"][mname] = {
            "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "vocab": cfg.vocab,
            "max_seq": cfg.max_seq, "head_dim": cfg.head_dim,
            "weights": f"{cfg.name}.safetensors",
            "hessians": f"hessians_{cfg.name}.safetensors",
            "n_params": cfg.n_params(),
        }
    print("[aot] lowering model graphs", flush=True)
    cfg3 = configs.MODELS["tiny3m"]
    export_model_graphs(cfg3, configs.VARIANTS, configs.PREFILL_BATCHES,
                        configs.DECODE_BATCHES, outdir, manifest)
    if not args.skip_9m:
        cfg9 = configs.MODELS["tiny9m"]
        export_model_graphs(cfg9, ("fp", "w8a8", "w4a8_fast"),
                            (1, 4), (1,), outdir, manifest)
    print("[aot] lowering GEMM kernel graphs", flush=True)
    export_gemm_graphs(outdir, manifest)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(manifest['graphs'])} graphs")
    with open(os.path.join(outdir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
