"""FP GEMM kernel — the FP16 baseline of every figure (f32 on this host).

Also ships `gemm_w4a8_unfused`, the paper's Fig. 4(b) 'vanilla' two-kernel
W4A8: a SEPARATE conversion kernel materializes the s8 weight matrix (an
extra HBM round-trip) before a plain W8A8 GEMM — the thing kernel fusion
removes.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common, w8a8


def _kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32)


def gemm_fp(x: jax.Array, w: jax.Array, *, interpret: bool = True):
    """x: f32[M,K], w: f32[K,N] -> f32[M,N]."""
    m, k = x.shape
    k_w, n = w.shape
    assert k == k_w
    (bm, bn), grid = common.gemm_tiles(m, n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)


# --- paper Fig. 4(b): unfused conversion + GEMM (baseline for ablation) ---

def _convert_kernel(wp_ref, o_ref):
    wp = wp_ref[...]
    lo16 = jax.lax.bitcast_convert_type((wp << 4).astype(jnp.uint8), jnp.int8)
    hi16 = jax.lax.bitcast_convert_type(wp & 0xF0, jnp.int8)
    o_ref[...] = jnp.stack([lo16, hi16], axis=1).reshape(
        2 * wp.shape[0], wp.shape[1])


def convert_sint4_to_s8x16(wp: jax.Array, *, interpret: bool = True):
    """Standalone conversion kernel: u8[K/2,N] packed -> s8[K,N] (x16)."""
    k2, n = wp.shape
    bn = common.largest_tile(n, common.TILE_N)
    return pl.pallas_call(
        _convert_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((k2, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((2 * k2, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((2 * k2, n), jnp.int8),
        interpret=interpret,
    )(wp)


def gemm_w4a8_unfused(xq, s_a, wp, s_w, *, interpret: bool = True):
    """Fig. 4(b): materialize converted weights, then separate W8A8 GEMM."""
    w16 = convert_sint4_to_s8x16(wp, interpret=interpret)
    return w8a8.gemm_w8a8(xq, s_a, w16, s_w / 16.0, interpret=interpret)


def vmem_footprint(m: int, n: int, k: int) -> int:
    (bm, bn), _ = common.gemm_tiles(m, n)
    return common.vmem_bytes(bm, bn, k, x_bytes=4, w_bytes_per_k=4)
