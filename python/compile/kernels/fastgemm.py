"""FastGEMM — the paper's W4A8 kernel (Sec. 5.3), as a Pallas kernel.

Single fused kernel = the paper's Fig. 4(c):
  1. SINT4toS8 conversion *inside* the GEMM kernel (no separate conversion
     kernel, no extra HBM round-trip): each packed byte expands to two s8
     values equal to 16x the int4 (nibble placed in the high 4 bits — the
     sign bit is reused, so no subtraction instruction is ever needed).
  2. s8 x s8 -> s32 matmul (MXU / TensorCore path).
  3. Per-channel dequant epilogue: acc * s_a * s_w / 16, folded into one
     multiply by pre-dividing s_w by 16.

Weights travel HBM->VMEM in packed form, so the kernel moves half the bytes
of the W8A8 kernel — exactly the memory-bound self-decode win the paper
reports (Table 5: up to 4.33x over QUIK at M=1).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(xq_ref, sa_ref, wp_ref, sw_ref, o_ref):
    wp = wp_ref[...]                                     # u8 [K/2, bn]
    # SINT4toS8: high-nibble placement == value * 16 (two's complement).
    lo16 = jax.lax.bitcast_convert_type((wp << 4).astype(jnp.uint8), jnp.int8)
    hi16 = jax.lax.bitcast_convert_type(wp & 0xF0, jnp.int8)
    w16 = jnp.stack([lo16, hi16], axis=1).reshape(2 * wp.shape[0],
                                                  wp.shape[1])
    acc = jax.lax.dot_general(xq_ref[...], w16, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    # epilogue: one FMA per output element; /16 pre-folded into s_w.
    o_ref[...] = (acc.astype(jnp.float32)
                  * sa_ref[...][:, None]
                  * (sw_ref[...] * (1.0 / 16.0))[None, :])


def gemm_w4a8_fast(xq: jax.Array, s_a: jax.Array, wp: jax.Array,
                   s_w: jax.Array, *, interpret: bool = True) -> jax.Array:
    """xq: s8[M,K], s_a: f32[M], wp: u8[K//2,N] (pack_int4), s_w: f32[N]."""
    m, k = xq.shape
    k2, n = wp.shape
    assert k == 2 * k2, (k, k2)
    (bm, bn), grid = common.gemm_tiles(m, n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((k2, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(xq, s_a, wp, s_w)


def vmem_footprint(m: int, n: int, k: int) -> int:
    """Bytes resident in VMEM per grid step (packed weights: 0.5 B/elem)."""
    (bm, bn), _ = common.gemm_tiles(m, n)
    return common.vmem_bytes(bm, bn, k, x_bytes=1, w_bytes_per_k=0.5)
