"""L1 — Pallas GEMM kernels for every bit-width paradigm in the paper.

variant registry used by model.py / aot.py / the rust manifest:

  fp         — Fig. 2 FP16 baseline (f32 on this host)       fpgemm.gemm_fp
  w8a8       — Fig. 2(c) SmoothQuant layout                  w8a8.gemm_w8a8
  w4a8_fast  — the paper's FastGEMM (Fig. 4(c))              fastgemm.gemm_w4a8_fast
  w4a8_group — Fig. 2(b) fine-grained baseline               finegrained.gemm_w4a8_grouped
  w4a8_asym  — 'Asym GEMM' baseline (Fig. 7)                 asym.gemm_w4a8_asym
  w4a16      — Fig. 2(a) GPTQ/AWQ deploy style               w4a16.gemm_w4a16
  w4a8_unfused — Fig. 4(b) two-kernel vanilla W4A8           fpgemm.gemm_w4a8_unfused
"""

from . import ref                       # noqa: F401
from .fastgemm import gemm_w4a8_fast    # noqa: F401
from .w8a8 import gemm_w8a8             # noqa: F401
from .finegrained import gemm_w4a8_grouped  # noqa: F401
from .asym import gemm_w4a8_asym        # noqa: F401
from .w4a16 import gemm_w4a16           # noqa: F401
from .fpgemm import gemm_fp, gemm_w4a8_unfused, convert_sint4_to_s8x16  # noqa: F401

VARIANTS = ("fp", "w8a8", "w4a8_fast", "w4a8_group", "w4a8_asym", "w4a16")
