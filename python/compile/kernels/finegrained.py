"""Fine-grained (group-wise) W4A8 GEMM kernel — paper Fig. 2(b), Eq. 5.

The hardware-UNFRIENDLY baseline the paper argues against: every K-group's
s32 partial sum must be dequantized (Integer2Float + FMA) back into an f32
accumulator before the next group — overhead that lands in the GEMM inner
loop and cancels the INT8 math advantage (Fig. 7 'fine-grained').

Kept as a first-class kernel so the ablation benches can measure exactly
that cost against FastGEMM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(xq_ref, sa_ref, wq_ref, sg_ref, o_ref, *, group: int):
    k = xq_ref.shape[1]
    n_groups = k // group
    bn = wq_ref.shape[1]
    bm = xq_ref.shape[0]

    def body(g, acc):
        xg = jax.lax.dynamic_slice(xq_ref[...], (0, g * group), (bm, group))
        wg = jax.lax.dynamic_slice(wq_ref[...], (g * group, 0), (group, bn))
        part = jax.lax.dot_general(xg, wg, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)
        # the per-group Integer2Float + FMA the paper's Fig. 7 measures:
        sg = jax.lax.dynamic_slice(sg_ref[...], (g, 0), (1, bn))
        return acc + part.astype(jnp.float32) * sg
    acc = jax.lax.fori_loop(0, n_groups, body,
                            jnp.zeros((bm, bn), jnp.float32))
    o_ref[...] = acc * sa_ref[...][:, None]


def gemm_w4a8_grouped(xq: jax.Array, s_a: jax.Array, wq: jax.Array,
                      s_g: jax.Array, group: int,
                      *, interpret: bool = True) -> jax.Array:
    """xq: s8[M,K], s_a: f32[M], wq: s8[K,N] (int4-valued), s_g: f32[K//g,N]."""
    m, k = xq.shape
    k_w, n = wq.shape
    assert k == k_w and k % group == 0
    g_rows = k // group
    (bm, bn), grid = common.gemm_tiles(m, n)
    return pl.pallas_call(
        functools.partial(_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((g_rows, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(xq, s_a, wq, s_g)


def vmem_footprint(m: int, n: int, k: int, group: int = 128) -> int:
    (bm, bn), _ = common.gemm_tiles(m, n)
    # int4 stored unpacked as s8 here (1 B/elem) + group scales
    return common.vmem_bytes(bm, bn, k, x_bytes=1, w_bytes_per_k=1) \
        + (k // group) * bn * 4
