"""Asymmetric W4A8 GEMM kernel — the paper's 'Asym GEMM' baseline (Fig. 7).

Zero-point handling forces the s8 subtraction modern GPUs do not provide
(PTX has no sub.s8); the correction term must be computed in s32.  We model
it faithfully: u4 weights GEMM in s8, then a widened zero-point correction
`z * rowsum(x)` subtracted in s32 before the per-channel dequant.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(xq_ref, sa_ref, wu_ref, sw_ref, z_ref, o_ref):
    xq = xq_ref[...]
    acc = jax.lax.dot_general(xq, wu_ref[...].astype(jnp.int8),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    # the widening fallback: zero-point correction in s32
    rs = jnp.sum(xq.astype(jnp.int32), axis=1)            # [bm]
    acc = acc - rs[:, None] * z_ref[...][None, :]
    o_ref[...] = (acc.astype(jnp.float32)
                  * sa_ref[...][:, None] * sw_ref[...][None, :])


def gemm_w4a8_asym(xq: jax.Array, s_a: jax.Array, wu: jax.Array,
                   s_w: jax.Array, z: jax.Array,
                   *, interpret: bool = True) -> jax.Array:
    """xq: s8[M,K], wu: u8[K,N] (uint4-valued), s_w: f32[N], z: s32[N]."""
    m, k = xq.shape
    k_w, n = wu.shape
    assert k == k_w
    (bm, bn), grid = common.gemm_tiles(m, n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(xq, s_a, wu, s_w, z)


def vmem_footprint(m: int, n: int, k: int) -> int:
    (bm, bn), _ = common.gemm_tiles(m, n)
    return common.vmem_bytes(bm, bn, k, x_bytes=1, w_bytes_per_k=1) + bn * 8
