"""W8A8 GEMM kernel (paper Fig. 2(c), Eq. 6/7) — the SmoothQuant layout.

Per-channel weight scales + per-token activation scales; dequantization
happens once, AFTER the s8 x s8 -> s32 GEMM.  This is the paper's "most
hardware-friendly" baseline and our serving engine's W8A8 variant.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(xq_ref, sa_ref, wq_ref, sw_ref, o_ref):
    acc = jax.lax.dot_general(xq_ref[...], wq_ref[...],
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    o_ref[...] = (acc.astype(jnp.float32)
                  * sa_ref[...][:, None] * sw_ref[...][None, :])


def gemm_w8a8(xq: jax.Array, s_a: jax.Array, wq: jax.Array, s_w: jax.Array,
              *, interpret: bool = True) -> jax.Array:
    """xq: s8[M,K], s_a: f32[M], wq: s8[K,N], s_w: f32[N] -> f32[M,N]."""
    m, k = xq.shape
    k_w, n = wq.shape
    assert k == k_w
    (bm, bn), grid = common.gemm_tiles(m, n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(xq, s_a, wq, s_w)


def vmem_footprint(m: int, n: int, k: int) -> int:
    (bm, bn), _ = common.gemm_tiles(m, n)
    return common.vmem_bytes(bm, bn, k, x_bytes=1, w_bytes_per_k=1)
