"""W4A16 GEMM kernel — paper Fig. 2(a), Eq. 4 (the GPTQ/AWQ deploy style).

Group-wise int4 weights are dequantized to float INSIDE the kernel, before
a float GEMM.  Low memory traffic (int4 weights) but the dequant runs on
the vector unit for every element and the matmul itself is float — the
reason W4A16 wins self-decode but loses pre-filling (paper Sec. 4.1).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(x_ref, wq_ref, sg_ref, o_ref, *, group: int):
    k, bn = wq_ref.shape
    g = k // group
    wf = (wq_ref[...].reshape(g, group, bn).astype(jnp.float32)
          * sg_ref[...][:, None, :]).reshape(k, bn)
    o_ref[...] = jnp.dot(x_ref[...], wf,
                         preferred_element_type=jnp.float32)


def gemm_w4a16(x: jax.Array, wq: jax.Array, s_g: jax.Array, group: int,
               *, interpret: bool = True) -> jax.Array:
    """x: f32[M,K], wq: s8[K,N] (int4-valued), s_g: f32[K//g,N] -> f32[M,N]."""
    m, k = x.shape
    k_w, n = wq.shape
    assert k == k_w and k % group == 0
    g_rows = k // group
    (bm, bn), grid = common.gemm_tiles(m, n)
    return pl.pallas_call(
        functools.partial(_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((g_rows, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, wq, s_g)


def vmem_footprint(m: int, n: int, k: int, group: int = 128) -> int:
    (bm, bn), _ = common.gemm_tiles(m, n)
    # x in f32 + unpacked-int4 weights + dequantized f32 copy of the block
    return common.vmem_bytes(bm, bn, k, x_bytes=4, w_bytes_per_k=1 + 4)
