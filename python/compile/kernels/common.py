"""Shared tiling helpers for the Pallas GEMM kernels.

All kernels use a 2-D grid over (M-tiles, N-tiles) and keep the full K
(reduction) extent resident in the block — the VMEM-budget arithmetic for
that choice is in `vmem_bytes` and reported by DESIGN.md §Perf.  On a real
TPU the HBM->VMEM schedule expressed by the BlockSpecs below is what the
paper expressed with CUDA threadblocks; `interpret=True` lowers the same
program to plain HLO so the CPU PJRT client can run it.
"""

import math

import jax.numpy as jnp

# Default tile ceiling.  128 matches the MXU systolic-array edge; blocks are
# shrunk to the largest divisor of the dim that stays <= the ceiling so the
# grid always covers the array exactly (no masking needed).
TILE_M = 128
TILE_N = 128

_DTYPE_BYTES = {jnp.int8.dtype: 1, jnp.uint8.dtype: 1,
                jnp.float32.dtype: 4, jnp.int32.dtype: 4}


def largest_tile(dim: int, ceiling: int) -> int:
    """Largest divisor of `dim` that is <= ceiling (>= 1)."""
    if dim <= ceiling:
        return dim
    for t in range(ceiling, 0, -1):
        if dim % t == 0:
            return t
    return 1


def gemm_tiles(m: int, n: int, tile_m: int = TILE_M, tile_n: int = TILE_N):
    """Pick (bm, bn) block shape and the grid for an MxN output."""
    bm = largest_tile(m, tile_m)
    bn = largest_tile(n, tile_n)
    return (bm, bn), (m // bm, n // bn)


def vmem_bytes(bm: int, bn: int, k: int, x_bytes: int, w_bytes_per_k: float,
               acc_bytes: int = 4) -> int:
    """Estimated VMEM residency for one grid step of a full-K GEMM block.

    x block: bm*k*x_bytes; w block: k*bn*w_bytes_per_k (0.5 for packed int4);
    accumulator/output: bm*bn*acc_bytes; scales are negligible.
    """
    return int(bm * k * x_bytes + math.ceil(k * bn * w_bytes_per_k)
               + bm * bn * acc_bytes)


def mxu_util_estimate(bm: int, bn: int, k: int, edge: int = 128) -> float:
    """Fraction of MXU lanes busy for a (bm x k) @ (k x bn) tile issue.

    The systolic array processes edge x edge tiles; partial tiles waste
    lanes.  This is the structural utilization estimate recorded in
    EXPERIMENTS.md §Perf (interpret mode gives no hardware counters).
    """
    eff_m = bm / (math.ceil(bm / edge) * edge)
    eff_n = bn / (math.ceil(bn / edge) * edge)
    eff_k = k / (math.ceil(k / edge) * edge)
    return eff_m * eff_n * eff_k
