"""Pure-jnp reference oracles for every GEMM variant in OdysseyLLM.

These are the CORRECTNESS ground truth for the Pallas kernels (checked by
pytest + hypothesis in python/tests/) and for the rust quant core (golden
files emitted by compile/goldens.py).

Conventions (shared verbatim with rust/src/quant/):
  * Activations  x  : f32[M, K]     (M tokens, K input features)
  * Weights      W  : f32[K, N]     (N output channels); quantized scales
                                    are per *output channel* -> s_w: f32[N]
  * INT4 values live in [-8, 7] stored two's-complement in the low nibble.
  * Packing is along K: P[k2, n] = (Wq[2*k2, n] & 0xF) | (Wq[2*k2+1, n] << 4)
    so a packed byte holds two K-adjacent values of the SAME output channel.
  * The FastGEMM trick (paper Fig. 4(d) / Fig. 5): unpacking places a nibble
    in the HIGH 4 bits of an s8, i.e. value*16; the INT32 accumulator result
    is divided by 16 in the per-channel dequant epilogue.
"""

import jax
import jax.numpy as jnp

INT4_MIN, INT4_MAX = -8, 7
INT8_MAX = 127


# --------------------------------------------------------------------------
# quantizers (reference semantics)
# --------------------------------------------------------------------------

def quant_act_per_token(x: jax.Array, eps: float = 1e-8):
    """Dynamic symmetric per-token INT8 quantization of activations.

    Returns (q: s8[M,K], s_a: f32[M]).  RTN-pt in the paper's Table 1.
    """
    s = jnp.max(jnp.abs(x), axis=-1) / INT8_MAX
    s = jnp.maximum(s, eps)
    q = jnp.clip(jnp.round(x / s[..., None]), -INT8_MAX, INT8_MAX)
    return q.astype(jnp.int8), s


def quant_weight_per_channel_sym(w: jax.Array, bits: int = 4,
                                 gamma=None, beta=None, eps: float = 1e-12):
    """Symmetric per-output-channel weight quantization (paper Eq. 8/9).

    gamma/beta are the (optional) LWC clip intensities, f32[N] each.
    Returns (q: s8[K,N] holding values in [qmin, qmax], s: f32[N]).
    """
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    hi = jnp.max(w, axis=0)
    lo = jnp.min(w, axis=0)
    if gamma is not None:
        hi = gamma * hi
    if beta is not None:
        lo = beta * lo
    s = jnp.maximum(jnp.maximum(jnp.abs(hi), jnp.abs(lo)) / qmax, eps)
    q = jnp.clip(jnp.round(w / s[None, :]), qmin, qmax)
    return q.astype(jnp.int8), s


def quant_weight_per_group_sym(w: jax.Array, group: int, bits: int = 4,
                               eps: float = 1e-12):
    """Symmetric group-wise (fine-grained, 'g128') weight quantization.

    Groups run along K.  Returns (q: s8[K,N], s: f32[K//group, N]).
    """
    K, N = w.shape
    assert K % group == 0, f"K={K} not divisible by group={group}"
    qmax = 2 ** (bits - 1) - 1
    wg = w.reshape(K // group, group, N)
    s = jnp.maximum(jnp.max(jnp.abs(wg), axis=1) / qmax, eps)  # [K//g, N]
    q = jnp.clip(jnp.round(wg / s[:, None, :]), -qmax - 1, qmax)
    return q.reshape(K, N).astype(jnp.int8), s


def quant_weight_per_channel_asym(w: jax.Array, bits: int = 4,
                                  eps: float = 1e-12):
    """Asymmetric per-channel UINT4 weight quantization (the paper's
    'Asym GEMM' baseline).  Returns (u: u8[K,N] in [0, 2^b-1],
    s: f32[N], z: s32[N] zero points)."""
    qmax = 2 ** bits - 1
    hi = jnp.max(w, axis=0)
    lo = jnp.min(w, axis=0)
    s = jnp.maximum((hi - lo) / qmax, eps)
    z = jnp.clip(jnp.round(-lo / s), 0, qmax).astype(jnp.int32)
    u = jnp.clip(jnp.round(w / s[None, :]) + z[None, :], 0, qmax)
    return u.astype(jnp.uint8), s, z


# --------------------------------------------------------------------------
# INT4 packing (paper Fig. 4(d) / Fig. 5 — SINT4toS8)
# --------------------------------------------------------------------------

def pack_int4(q: jax.Array) -> jax.Array:
    """Pack s8[K,N] int4 values (in [-8,7]) into u8[K//2, N] bytes.

    Two K-adjacent values per byte: low nibble = even k, high = odd k.
    """
    K, N = q.shape
    assert K % 2 == 0
    u = jnp.asarray(q, jnp.int32) & 0xF
    lo = u[0::2, :]
    hi = u[1::2, :]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4_x16(p: jax.Array) -> jax.Array:
    """SINT4toS8: unpack u8[K2,N] into s8[2*K2,N] where every element is
    16x the original int4 value (nibble placed in the high 4 bits).

    This is the FastGEMM conversion: no subtraction, sign bit reused.
    """
    K2, N = p.shape
    lo16 = jax.lax.bitcast_convert_type((p << 4).astype(jnp.uint8), jnp.int8)
    hi16 = jax.lax.bitcast_convert_type(p & 0xF0, jnp.int8)
    out = jnp.stack([lo16, hi16], axis=1)                          # [K2,2,N]
    return out.reshape(2 * K2, N)


def unpack_int4(p: jax.Array) -> jax.Array:
    """Exact inverse of pack_int4 -> s8[2*K2, N] with values in [-8,7].

    This is the 'vanilla' UINT4toS8 path that needs extra arithmetic (the
    conversion FastGEMM avoids): x16 then an arithmetic /16.
    """
    w16 = unpack_int4_x16(p).astype(jnp.int32)
    return (w16 // 16).astype(jnp.int8)  # exact: every value is 16*w


# --------------------------------------------------------------------------
# GEMM variant oracles.  All return f32[M, N].
# --------------------------------------------------------------------------

def _idot(a, b):
    """Integer matmul with an s32 accumulator (the MXU/TensorCore path)."""
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def gemm_fp(x: jax.Array, w: jax.Array) -> jax.Array:
    """FP baseline (paper's FP16; f32 on this CPU testbed)."""
    return jnp.dot(x, w)


def gemm_w8a8(xq: jax.Array, s_a: jax.Array, wq: jax.Array,
              s_w: jax.Array) -> jax.Array:
    """W8A8 per-token/per-channel (paper Eq. 6/7): dequant AFTER the GEMM."""
    acc = _idot(xq, wq)
    return acc.astype(jnp.float32) * s_a[:, None] * s_w[None, :]


def gemm_w4a8_fast(xq: jax.Array, s_a: jax.Array, wp: jax.Array,
                   s_w: jax.Array) -> jax.Array:
    """FastGEMM: packed int4 weights, x16 high-nibble unpack fused with the
    int GEMM, single per-channel dequant epilogue dividing by 16."""
    w16 = unpack_int4_x16(wp)
    acc = _idot(xq, w16)
    return acc.astype(jnp.float32) * (s_a[:, None] * (s_w[None, :] / 16.0))


def gemm_w4a8_grouped(xq: jax.Array, s_a: jax.Array, wq: jax.Array,
                      s_g: jax.Array, group: int) -> jax.Array:
    """Fine-grained W4A8 (paper Eq. 5): per-group dequantize WHILE
    accumulating — the hardware-unfriendly baseline."""
    M, K = xq.shape
    _, N = wq.shape
    G = K // group
    xg = xq.reshape(M, G, group)
    wg = wq.reshape(G, group, N)
    acc = jnp.zeros((M, N), jnp.float32)
    for g in range(G):
        part = _idot(xg[:, g, :], wg[g])                 # s32 [M,N]
        acc = acc + part.astype(jnp.float32) * s_g[g][None, :]
    return acc * s_a[:, None]


def gemm_w4a8_asym(xq: jax.Array, s_a: jax.Array, wu: jax.Array,
                   s_w: jax.Array, z: jax.Array) -> jax.Array:
    """Asymmetric W4A8: zero-point subtraction forces the widening the
    paper's 'Asym GEMM' pays for.  out = s_a*s_w*((Xq·U) - z*rowsum(Xq))."""
    acc = _idot(xq, wu.astype(jnp.int8))                  # u4 fits in s8
    rs = jnp.sum(xq.astype(jnp.int32), axis=1)            # [M]
    corr = rs[:, None] * z[None, :]
    return (acc - corr).astype(jnp.float32) * s_a[:, None] * s_w[None, :]


def gemm_w4a16(x: jax.Array, wq: jax.Array, s_g: jax.Array,
               group: int) -> jax.Array:
    """W4A16 (paper Eq. 4): dequantize group-wise int4 weights to float
    BEFORE an FP GEMM (the GPTQ/AWQ deployment style)."""
    K, N = wq.shape
    G = K // group
    wf = wq.reshape(G, group, N).astype(jnp.float32) * s_g[:, None, :]
    return jnp.dot(x, wf.reshape(K, N))


# --------------------------------------------------------------------------
# end-to-end reference linears (fp32 in, fp32 out) used by the model oracle
# --------------------------------------------------------------------------

def linear_fp(x, w):
    return gemm_fp(x, w)


def linear_w8a8(x, wq, s_w):
    xq, s_a = quant_act_per_token(x)
    return gemm_w8a8(xq, s_a, wq, s_w)


def linear_w4a8_fast(x, wp, s_w):
    xq, s_a = quant_act_per_token(x)
    return gemm_w4a8_fast(xq, s_a, wp, s_w)


def linear_w4a8_grouped(x, wq, s_g, group):
    xq, s_a = quant_act_per_token(x)
    return gemm_w4a8_grouped(xq, s_a, wq, s_g, group)


def linear_w4a8_asym(x, wu, s_w, z):
    xq, s_a = quant_act_per_token(x)
    return gemm_w4a8_asym(xq, s_a, wu, s_w, z)


def linear_w4a16(x, wq, s_g, group):
    return gemm_w4a16(x, wq, s_g, group)
