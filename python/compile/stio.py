"""Minimal safetensors reader/writer (the format rust/src/formats mirrors).

Layout: 8-byte little-endian header length, JSON header mapping tensor name
-> {dtype, shape, data_offsets}, then the raw little-endian tensor bytes.
Only the dtypes this project uses are supported.
"""

import json

import numpy as np

_DTYPES = {
    "F32": np.float32, "F64": np.float64, "I32": np.int32, "I8": np.int8,
    "U8": np.uint8, "I64": np.int64, "U16": np.uint16,
}
_NAMES = {np.dtype(v): k for k, v in _DTYPES.items()}


def save(path: str, tensors: dict):
    """tensors: dict name -> np.ndarray (C-contiguous)."""
    header = {}
    offset = 0
    blobs = []
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name])
        nbytes = arr.nbytes
        header[name] = {
            "dtype": _NAMES[arr.dtype],
            "shape": list(arr.shape),
            "data_offsets": [offset, offset + nbytes],
        }
        blobs.append(arr.tobytes())
        offset += nbytes
    hjson = json.dumps(header, separators=(",", ":")).encode()
    pad = (8 - len(hjson) % 8) % 8     # keep data 8-aligned like upstream
    hjson += b" " * pad
    with open(path, "wb") as f:
        f.write(len(hjson).to_bytes(8, "little"))
        f.write(hjson)
        for b in blobs:
            f.write(b)


def load(path: str) -> dict:
    with open(path, "rb") as f:
        n = int.from_bytes(f.read(8), "little")
        header = json.loads(f.read(n))
        data = f.read()
    out = {}
    for name, meta in header.items():
        if name == "__metadata__":
            continue
        lo, hi = meta["data_offsets"]
        arr = np.frombuffer(data[lo:hi], dtype=_DTYPES[meta["dtype"]])
        out[name] = arr.reshape(meta["shape"]).copy()
    return out
