"""Synthetic corpus + evaluation-task generators.

Stand-in for the paper's LAMBADA / C4 / WikiText2 / CommonSenseQA / MMLU
(none downloadable here — see DESIGN.md substitution index).  The corpus is
a probabilistic template grammar with long-range dependencies so that

  * held-out perplexity is meaningful (C4/WikiText analogue),
  * a LAMBADA-style cloze exists: the final word of a paragraph is
    recoverable only from earlier context (coreference copy),
  * multiple-choice tasks exist whose wrong answers violate grammar-class
    constraints (CommonSense-QA analogue),
  * a few-shot category task exists (MMLU analogue).

Everything is deterministic given the seed; the token stream and task files
are written into artifacts/ for the rust evaluator.
"""

import json
import os

import numpy as np

PAD, BOS, EOS = 0, 1, 2
# token id blocks (vocab 512)
THE, A, AND, THEN, DOT, COMMA, SO, BUT, WHO, ISA, QMARK = range(3, 14)
N_NOUN, N_VERB, N_ADJ, N_ADV, N_CAT = 120, 80, 60, 24, 4
NOUN0 = 16
VERB0 = NOUN0 + N_NOUN          # 136
ADJ0 = VERB0 + N_VERB           # 216
ADV0 = ADJ0 + N_ADJ             # 276
CAT0 = ADV0 + N_ADV             # 300
VOCAB = 512

N_CLASS = 8                      # noun/verb agreement classes


def noun_class(n):
    return n % N_CLASS


def verb_class(v):
    return v % N_CLASS


def noun_category(n):
    return n % N_CAT


class Grammar:
    """Template grammar with agreement constraints."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        # each verb class accepts subjects of one noun class and objects of
        # another (fixed by seed) — the "commonsense" structure.
        r = np.random.default_rng(1234)
        self.verb_subj = r.integers(0, N_CLASS, size=N_CLASS)
        self.verb_obj = r.integers(0, N_CLASS, size=N_CLASS)

    def _noun(self, cls=None):
        while True:
            n = int(self.rng.integers(0, N_NOUN))
            if cls is None or noun_class(n) == cls:
                return NOUN0 + n

    def _verb(self, cls=None):
        while True:
            v = int(self.rng.integers(0, N_VERB))
            if cls is None or verb_class(v) == cls:
                return VERB0 + v

    def sentence(self, subj=None, allow_adj=True):
        """One grammatical sentence; returns (tokens, subject_token)."""
        rng = self.rng
        if subj is None:
            subj = self._noun()
        scls = noun_class(subj - NOUN0)
        # verb whose subject class matches
        vcands = [v for v in range(N_CLASS) if self.verb_subj[v] == scls]
        vcls = int(rng.choice(vcands)) if vcands else scls
        verb = self._verb(vcls)
        obj = self._noun(int(self.verb_obj[vcls]))
        toks = [THE]
        if allow_adj and rng.random() < 0.4:
            toks.append(ADJ0 + int(rng.integers(0, N_ADJ)))
        toks += [subj, verb, THE, obj]
        if rng.random() < 0.25:
            toks.append(ADV0 + int(rng.integers(0, N_ADV)))
        toks.append(DOT)
        return toks, subj

    def paragraph(self):
        """2-3 sentences; final sentence repeats the first subject after
        'then the' — the LAMBADA-style long-range copy."""
        toks = [BOS]
        first, subj0 = self.sentence()
        toks += first
        for _ in range(int(self.rng.integers(0, 2))):
            s, _ = self.sentence()
            toks += s
        # coreferent final sentence: 'then the SUBJ ...' with no adjective,
        # so the copy target always follows the THEN-THE bigram (a clean
        # induction-head pattern the LAMBADA-style cloze probes)
        s, _ = self.sentence(subj=subj0, allow_adj=False)
        toks += [THEN] + s
        toks.append(EOS)
        return toks, subj0

    def fact(self, noun=None):
        """'the NOUN isa CAT .' — the MMLU-style category fact."""
        if noun is None:
            noun = NOUN0 + int(self.rng.integers(0, N_NOUN))
        cat = CAT0 + noun_category(noun - NOUN0)
        return [THE, noun, ISA, cat, DOT], noun, cat


def gen_corpus(n_tokens: int, seed: int = 0) -> np.ndarray:
    g = Grammar(seed)
    out = []
    while len(out) < n_tokens:
        if g.rng.random() < 0.15:
            f, _, _ = g.fact()
            out += [BOS] + f + [EOS]
        else:
            p, _ = g.paragraph()
            out += p
    return np.asarray(out[:n_tokens], dtype=np.uint16)


def gen_cloze(n: int, seed: int = 100):
    """LAMBADA analogue: context ends right before the repeated subject.

    Returns list of {ctx, target} — candidates are all nouns implicitly.
    """
    g = Grammar(seed)
    tasks = []
    while len(tasks) < n:
        p, subj = g.paragraph()
        # target = last occurrence of subj (in the final sentence)
        idxs = [i for i, t in enumerate(p) if t == subj]
        if len(idxs) < 2:
            continue
        cut = idxs[-1]
        if cut < 8 or cut > 120:
            continue
        tasks.append({"ctx": [int(t) for t in p[:cut]], "target": int(subj)})
    return tasks


def gen_mcq(n: int, seed: int = 200):
    """CommonSenseQA analogue: pick the object noun of the right class;
    distractors come from wrong classes."""
    g = Grammar(seed)
    tasks = []
    while len(tasks) < n:
        toks, subj = g.sentence()
        # find object position: the token after the second THE
        the_idx = [i for i, t in enumerate(toks) if t == THE]
        if len(the_idx) < 2:
            continue
        oi = the_idx[1] + 1
        obj = toks[oi]
        ocls = noun_class(obj - NOUN0)
        wrong = []
        while len(wrong) < 3:
            cand = g._noun()
            if noun_class(cand - NOUN0) != ocls and cand != obj:
                wrong.append(cand)
        cands = [int(obj)] + [int(w) for w in wrong]
        order = g.rng.permutation(4)
        cands = [cands[i] for i in order]
        answer = int(np.where(order == 0)[0][0])
        tasks.append({"ctx": [BOS] + [int(t) for t in toks[:oi]],
                      "candidates": cands, "answer": answer})
    return tasks


def gen_fewshot(n: int, shots: int = 3, seed: int = 300):
    """MMLU analogue: k-shot category facts, then query 'the NOUN isa ?'."""
    g = Grammar(seed)
    tasks = []
    for _ in range(n):
        ctx = [BOS]
        for _ in range(shots):
            f, _, _ = g.fact()
            ctx += f
        f, noun, cat = g.fact()
        ctx += f[:3]                      # the NOUN isa
        cands = [CAT0 + c for c in range(N_CAT)]
        tasks.append({"ctx": [int(t) for t in ctx],
                      "candidates": cands,
                      "answer": int(cat - CAT0)})
    return tasks


def write_all(outdir: str, train_tokens: int = 600_000,
              val_tokens: int = 60_000, seed: int = 0):
    os.makedirs(outdir, exist_ok=True)
    train = gen_corpus(train_tokens, seed=seed)
    val = gen_corpus(val_tokens, seed=seed + 1)
    train.tofile(os.path.join(outdir, "corpus_train.bin"))
    val.tofile(os.path.join(outdir, "corpus_val.bin"))
    tasks = {
        "cloze": gen_cloze(400),
        "mcq": gen_mcq(400),
        "fewshot": gen_fewshot(300),
        "vocab": VOCAB,
        "noun_range": [NOUN0, NOUN0 + N_NOUN],
    }
    with open(os.path.join(outdir, "tasks.json"), "w") as f:
        json.dump(tasks, f)
    return train, val, tasks
