"""Calibration pass: per-linear input Hessians + activation statistics.

The paper calibrates on 128 random C4 sequences; we use 128 sequences of
the synthetic corpus.  For every linear-group input tap (q/k/v share one,
gate/up share one) we accumulate

  H        = 2 * sum_t x_t x_t^T / T          (GPTQ, Eq. 10's H_F)
  absmax   = max_t |x_t|   per input channel  (SmoothQuant)
  absmean  = mean_t |x_t|  per input channel  (AWQ)

and store them in artifacts/hessians_<model>.safetensors for the rust
quantizer (python never runs at request/quantize time on the rust side).
"""

import os

import numpy as np

import jax.numpy as jnp

from . import configs, model, stio
from .configs import ModelConfig

# tap name -> matrices consuming that input
TAP_CONSUMERS = {
    "attn_in": ("wq", "wk", "wv"),
    "attn_out_in": ("wo",),
    "mlp_in": ("w_gate", "w_up"),
    "mlp_down_in": ("w_down",),
}


def calib_sequences(tokens: np.ndarray, n_seq: int = 128, seq: int = 64,
                    seed: int = 11):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(tokens) - seq, size=n_seq)
    return np.stack([tokens[i:i + seq] for i in idx]).astype(np.int32)


def run_calibration(cfg: ModelConfig, ws: dict, calib_tokens: np.ndarray,
                    batch: int = 8):
    """Returns dict name -> np.ndarray with hessian/absmax/absmean/sample
    entries per layer tap (+ lm_head_in)."""
    flat = model.quantize_weights(cfg, ws, "fp")
    stats = {}

    def acc(name, x):
        x = np.asarray(x, np.float64)
        e = stats.setdefault(name, {
            "h": np.zeros((x.shape[1], x.shape[1])),
            "absmax": np.zeros(x.shape[1]),
            "abssum": np.zeros(x.shape[1]),
            "count": 0, "sample": None})
        e["h"] += x.T @ x
        e["absmax"] = np.maximum(e["absmax"], np.abs(x).max(axis=0))
        e["abssum"] += np.abs(x).sum(axis=0)
        if e["sample"] is None:
            e["sample"] = x[:64].astype(np.float32)
        e["count"] += x.shape[0]

    n_seq, seq = calib_tokens.shape
    for b0 in range(0, n_seq, batch):
        toks = jnp.asarray(calib_tokens[b0:b0 + batch])
        length = jnp.full((toks.shape[0],), seq, jnp.int32)
        (_logits, _ks, _vs), taps = model.prefill(
            cfg, "fp", toks, length, *flat, use_ref=True, collect_taps=True)
        # taps arrive layer-by-layer: 4 per layer, then lm_head_in
        ti = 0
        for layer in range(cfg.n_layers):
            for tap_name in ("attn_in", "attn_out_in", "mlp_in",
                             "mlp_down_in"):
                name, x = taps[ti]
                assert name == tap_name
                acc(f"layers.{layer}.{tap_name}", x)
                ti += 1
        name, x = taps[ti]
        assert name == "lm_head_in"
        acc("lm_head_in", x)

    out = {}
    for name, e in stats.items():
        out[f"{name}.hessian"] = (2.0 * e["h"] / e["count"]).astype(
            np.float32)
        out[f"{name}.absmax"] = e["absmax"].astype(np.float32)
        out[f"{name}.absmean"] = (e["abssum"] / e["count"]).astype(
            np.float32)
        out[f"{name}.sample"] = e["sample"]
    return out


def save_calibration(cfg: ModelConfig, stats: dict,
                     outdir: str = "../artifacts"):
    os.makedirs(outdir, exist_ok=True)
    stio.save(os.path.join(outdir, f"hessians_{cfg.name}.safetensors"),
              stats)


def matrix_tap(name: str) -> str:
    """Canonical matrix name -> its calibration tap name."""
    leaf = name.split(".")[-1]
    for tap, mats in TAP_CONSUMERS.items():
        if leaf in mats:
            prefix = name.rsplit(".", 1)[0]
            return f"{prefix}.{tap}"
    raise KeyError(name)
