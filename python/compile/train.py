"""Build-time training of the tiny LLaMA models on the synthetic corpus.

This produces the FP32 checkpoints every quantization experiment starts
from (the stand-in for the paper's pretrained LLaMA-1/2 — see DESIGN.md).
Hand-rolled AdamW (optax is not available in this environment).

Run via `make artifacts` (aot.py drives it); the loss curve is written to
artifacts/train_log_<model>.json and summarized in EXPERIMENTS.md.
"""

import functools
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import configs, data, model, stio
from .configs import ModelConfig


def train_forward(cfg: ModelConfig, ws: dict, tokens):
    """Lean pure-jnp forward for training: tokens i32[B,S] -> logits."""
    B, S = tokens.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    x = jnp.take(ws["embed"], tokens, axis=0)
    pos = jnp.arange(S)[None, :].repeat(B, 0)
    cos, sin = model.rope_tables(cfg, pos)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    idx = jnp.arange(S)
    mask = (idx[None, :] <= idx[:, None])[None, None, :, :]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        h = model.rms_norm(x, ws[p + "attn_norm"], cfg.norm_eps)
        q = (h @ ws[p + "wq"]).reshape(B, S, H, Dh)
        k = (h @ ws[p + "wk"]).reshape(B, S, H, Dh)
        v = (h @ ws[p + "wv"]).reshape(B, S, H, Dh)
        q = model.apply_rope(q, cos, sin).transpose(0, 2, 1, 3)
        k = model.apply_rope(k, cos, sin).transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        sc = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(Dh)
        sc = jnp.where(mask, sc, model.NEG_INF)
        att = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3)
        x = x + o.reshape(B, S, -1) @ ws[p + "wo"]
        h = model.rms_norm(x, ws[p + "mlp_norm"], cfg.norm_eps)
        act = model.swiglu(h @ ws[p + "w_gate"], h @ ws[p + "w_up"])
        x = x + act @ ws[p + "w_down"]
    x = model.rms_norm(x, ws["norm_f"], cfg.norm_eps)
    return x @ ws["lm_head"]


def loss_fn(cfg, ws, tokens):
    """Next-token cross entropy over tokens i32[B,S+1]."""
    logits = train_forward(cfg, ws, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def adamw_update(ws, grads, m, v, step, lr, b1=0.9, b2=0.95, eps=1e-8,
                 wd=0.01):
    new_ws, new_m, new_v = {}, {}, {}
    bc1 = 1 - b1 ** step
    bc2 = 1 - b2 ** step
    for k in ws:
        g = grads[k]
        m_k = b1 * m[k] + (1 - b1) * g
        v_k = b2 * v[k] + (1 - b2) * g * g
        upd = (m_k / bc1) / (jnp.sqrt(v_k / bc2) + eps)
        decay = wd if ws[k].ndim == 2 else 0.0
        new_ws[k] = ws[k] - lr * (upd + decay * ws[k])
        new_m[k] = m_k
        new_v[k] = v_k
    return new_ws, new_m, new_v


def batches(tokens: np.ndarray, batch: int, seq: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        yield np.stack([tokens[i:i + seq + 1] for i in idx]).astype(np.int32)


def eval_ppl(cfg, ws, val: np.ndarray, seq: int = 128, max_chunks: int = 64):
    """Held-out perplexity of the f32 model (python-side reference)."""
    lf = jax.jit(functools.partial(loss_fn, cfg))
    tot, cnt = 0.0, 0
    for i in range(0, min(len(val) - seq - 1, max_chunks * seq), seq):
        chunk = val[i:i + seq + 1][None, :].astype(np.int32)
        tot += float(lf(ws, jnp.asarray(chunk)))
        cnt += 1
    return math.exp(tot / max(cnt, 1))


def train(cfg: ModelConfig, train_tokens: np.ndarray, val_tokens: np.ndarray,
          steps: int = 800, batch: int = 8, seq: int = 128,
          lr: float = 3e-3, seed: int = 0, log_every: int = 25,
          outdir: str = "../artifacts"):
    ws = {k: jnp.asarray(v) for k, v in model.init_weights(cfg, seed).items()}
    m = {k: jnp.zeros_like(v) for k, v in ws.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in ws.items()}
    vg = jax.jit(jax.value_and_grad(functools.partial(loss_fn, cfg),
                                    argnums=0))
    gen = batches(train_tokens, batch, seq, seed + 7)
    log = []
    t0 = time.time()
    for step in range(1, steps + 1):
        tok = jnp.asarray(next(gen))
        cur_lr = lr * 0.5 * (1 + math.cos(math.pi * step / steps))
        cur_lr = max(cur_lr, lr * 0.05)
        if step < 20:                          # warmup
            cur_lr = lr * step / 20
        loss, grads = vg(ws, tok)
        ws, m, v = adamw_update(ws, grads, m, v, step, cur_lr)
        if step % log_every == 0 or step == 1:
            log.append({"step": step, "loss": float(loss),
                        "lr": cur_lr, "elapsed_s": time.time() - t0})
            print(f"[train {cfg.name}] step {step:4d} "
                  f"loss {float(loss):.4f} lr {cur_lr:.2e}", flush=True)
    ppl = eval_ppl(cfg, ws, val_tokens, seq)
    log.append({"final_val_ppl": ppl})
    print(f"[train {cfg.name}] final val ppl {ppl:.3f}")
    os.makedirs(outdir, exist_ok=True)
    stio.save(os.path.join(outdir, f"{cfg.name}.safetensors"),
              {k: np.asarray(vv) for k, vv in ws.items()})
    with open(os.path.join(outdir, f"train_log_{cfg.name}.json"), "w") as f:
        json.dump(log, f, indent=1)
    return ws, ppl
