"""Model / variant / export configuration shared by L2 (python) and L3 (rust).

The canonical weight ordering defined here is the contract the rust side
relies on when assembling PJRT executable arguments; aot.py additionally
writes artifacts/manifest.json so rust never has to re-derive it.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int
    max_seq: int = 256
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return self.n_layers * per_layer + 2 * v * d + d


# LLaMA-architecture tiny models trained at build time (substitutes for the
# paper's LLaMA-1/2 7B..70B — see DESIGN.md substitution index).  Dims keep
# every K/N divisible by 128 where possible so kernel tiles stay MXU-shaped.
MODELS = {
    "tiny3m": ModelConfig("tiny3m", d_model=256, n_layers=4, n_heads=8,
                          d_ff=768, vocab=512, max_seq=256),
    "tiny9m": ModelConfig("tiny9m", d_model=384, n_layers=6, n_heads=8,
                          d_ff=1152, vocab=512, max_seq=256),
}

DEFAULT_MODEL = "tiny3m"

# GEMM bit-width variants (see kernels/__init__.py for the kernel mapping).
VARIANTS = ("fp", "w8a8", "w4a8_fast", "w4a8_group", "w4a8_asym", "w4a16")

# group size for the fine-grained baselines ("g128" in the paper; scaled to
# the tiny models' K so there are >= 2 groups per channel).
GROUP_SIZE = 64

# serving buckets exported by aot.py
PREFILL_BATCHES = (1, 4)
DECODE_BATCHES = (1, 4)
PREFILL_SEQ = 128

# paper Table 5 / Fig. 7 GEMM shapes: (N, K) pairs; M=1024 context stage,
# M=1 self-decode stage.
PAPER_GEMM_NK = ((4096, 4096), (1024, 8192), (11088, 4096), (5120, 5120))
PAPER_GEMM_MS = (1024, 1)
# CPU-scaled shapes for quick measured benches (same aspect ratios).
CPU_GEMM_NK = ((1024, 1024), (256, 2048), (2816, 1024), (1280, 1280))


@dataclass
class LayerWeights:
    """Canonical per-layer weight names, in argument order."""
    names: tuple = ("attn_norm", "wq", "wk", "wv", "wo",
                    "mlp_norm", "w_gate", "w_up", "w_down")


# Matrices that get quantized (per layer); norms/embeddings stay f32.
LAYER_MATRICES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")
TAIL_WEIGHTS = ("norm_f", "embed", "lm_head")


def weight_names(cfg: ModelConfig):
    """Flat canonical weight name list: layers then tail."""
    out = []
    for i in range(cfg.n_layers):
        for n in LayerWeights.names:
            out.append(f"layers.{i}.{n}")
    out.extend(TAIL_WEIGHTS)
    return out


def matrix_shape(cfg: ModelConfig, name: str):
    """(K, N) shape of a quantizable matrix, by canonical name."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    leaf = name.split(".")[-1]
    return {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "w_gate": (d, f), "w_up": (d, f), "w_down": (f, d),
        "embed": (v, d), "lm_head": (d, v),
    }[leaf]


def quantized_matrix_names(cfg: ModelConfig):
    """Canonical names of every matrix the quantizer touches."""
    return [f"layers.{i}.{m}" for i in range(cfg.n_layers)
            for m in LAYER_MATRICES]
