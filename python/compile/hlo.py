"""HLO-text lowering helper — the L2 -> L3 interchange format.

HLO *text* (not serialized HloModuleProto) is the only format the rust
side's xla_extension 0.5.1 accepts: jax >= 0.5 emits protos with 64-bit
instruction ids which old XLA rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly.  Always lower with
return_tuple=True and unwrap with `to_tuple()` on the rust side.
"""

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    """Convert a jax `Lowered` to XLA HLO text via stablehlo.

    `print_large_constants=True` is load-bearing: the default printer
    elides big constants as `{...}`, which the old text parser silently
    reads back as ZEROS (e.g. every arange/iota folded at trace time).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text(print_large_constants=True)


def lower_to_text(fn, *example_args) -> str:
    """jit + lower `fn` at the given example args and emit HLO text."""
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def export(fn, example_args, out_path: str) -> dict:
    """Lower and write HLO text; return a manifest entry describing the
    parameter/output interface (shapes, dtypes, order) for the rust side."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    flat, _ = jax.tree_util.tree_flatten(example_args)
    out_tree = jax.eval_shape(fn, *example_args)
    out_flat, _ = jax.tree_util.tree_flatten(out_tree)
    return {
        "path": out_path,
        "params": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                   for a in flat],
        "outputs": [{"shape": list(o.shape), "dtype": str(o.dtype)}
                    for o in out_flat],
    }
