"""Python reference implementations of the OdysseyLLM quantization recipe.

The production quantizer lives in rust (rust/src/quant/); these numpy/jax
versions are (a) the cross-check goldens for the rust unit tests, and
(b) the faithful gradient-descent LWC (OmniQuant-style) that the rust side
replaces with a deterministic grid search (see DESIGN.md substitution
index — both minimize the same per-channel MSE objective).

Matrix convention matches kernels/ref.py: W is f32[K, N], scales are per
OUTPUT channel (N); the GPTQ Hessian is over the INPUT dim (K):
H = 2 X^T X with X the f32[T, K] calibration activations.
"""

import numpy as np

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# plain RTN
# --------------------------------------------------------------------------

def rtn_per_channel(w: np.ndarray, bits: int, gamma=None, beta=None):
    """Symmetric per-output-channel RTN.  Returns (q s8[K,N], s f32[N])."""
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    hi = w.max(axis=0)
    lo = w.min(axis=0)
    if gamma is not None:
        hi = gamma * hi
    if beta is not None:
        lo = beta * lo
    s = np.maximum(np.maximum(np.abs(hi), np.abs(lo)) / qmax, 1e-12)
    q = np.clip(np.round(w / s[None, :]), qmin, qmax)
    return q.astype(np.int8), s.astype(np.float32)


def rtn_per_group(w: np.ndarray, group: int, bits: int):
    """Symmetric group-wise RTN (g128 style).  (q s8[K,N], s f32[K//g,N])."""
    K, N = w.shape
    qmax = 2 ** (bits - 1) - 1
    wg = w.reshape(K // group, group, N)
    s = np.maximum(np.abs(wg).max(axis=1) / qmax, 1e-12)
    q = np.clip(np.round(wg / s[:, None, :]), -qmax - 1, qmax)
    return q.reshape(K, N).astype(np.int8), s.astype(np.float32)


def dequant_per_channel(q: np.ndarray, s: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * s[None, :]


# --------------------------------------------------------------------------
# LWC — Learnable Weight Clipping (paper Sec. 5.1, Eq. 8/9)
# --------------------------------------------------------------------------

LWC_GRID = np.round(np.arange(0.40, 1.0001, 0.025), 6)


def lwc_grid_search(w: np.ndarray, bits: int = 4, grid=LWC_GRID):
    """Deterministic per-channel grid search over (gamma, beta) minimizing
    the per-channel fake-quant MSE.  EXACTLY mirrors rust quant::lwc.

    Returns (gamma f32[N], beta f32[N]).
    """
    K, N = w.shape
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    hi = w.max(axis=0)
    lo = w.min(axis=0)
    best_mse = np.full(N, np.inf, np.float64)
    best_g = np.ones(N, np.float32)
    best_b = np.ones(N, np.float32)
    for g in grid:
        for b in grid:
            s = np.maximum(np.maximum(np.abs(g * hi), np.abs(b * lo)) / qmax,
                           1e-12)
            q = np.clip(np.round(w / s[None, :]), qmin, qmax)
            err = w - q * s[None, :]
            mse = np.mean(err * err, axis=0)
            better = mse < best_mse
            best_mse = np.where(better, mse, best_mse)
            best_g = np.where(better, g, best_g)
            best_b = np.where(better, b, best_b)
    return best_g.astype(np.float32), best_b.astype(np.float32)


def lwc_sgd(w: np.ndarray, bits: int = 4, steps: int = 120, lr: float = 5e-3):
    """OmniQuant-style learnable clipping via STE gradient descent (the
    paper's actual method).  Returns (gamma f32[N], beta f32[N])."""
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    wj = jnp.asarray(w)
    hi = jnp.max(wj, axis=0)
    lo = jnp.min(wj, axis=0)

    def fakequant_mse(params):
        g, b = params
        s = jnp.maximum(jnp.maximum(jnp.abs(g * hi), jnp.abs(b * lo)) / qmax,
                        1e-12)
        x = wj / s[None, :]
        # straight-through round
        xq = x + jax.lax.stop_gradient(jnp.clip(jnp.round(x), qmin, qmax) - x)
        err = wj - xq * s[None, :]
        return jnp.mean(err * err)

    grad = jax.jit(jax.grad(fakequant_mse))
    g = jnp.ones_like(hi)
    b = jnp.ones_like(lo)
    for _ in range(steps):
        dg, db = grad((g, b))
        g = jnp.clip(g - lr * dg, 0.3, 1.0)
        b = jnp.clip(b - lr * db, 0.3, 1.0)
    return np.asarray(g, np.float32), np.asarray(b, np.float32)


# --------------------------------------------------------------------------
# GPTQ — Hessian-based training-free compensation (paper Sec. 5.2)
# --------------------------------------------------------------------------

def gptq_quantize(w: np.ndarray, hessian: np.ndarray, bits: int = 4,
                  scale: np.ndarray = None, percdamp: float = 0.01,
                  act_order: bool = False, group: int = 0):
    """GPTQ over a f32[K,N] matrix with input-dim Hessian f32[K,K].

    `scale`: fixed per-output-channel scales (e.g. from LWC); computed via
    RTN when None and group==0.  `group` > 0 switches to fine-grained
    scales recomputed per group (the GPTQ-g128 baseline).  `act_order`
    processes input dims by decreasing Hessian diagonal (the paper's 'ro').

    Returns (q s8[K,N], scales, perm or None).  Scales shape: [N] when
    group==0 else [K//group, N].
    """
    K, N = w.shape
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    W = w.astype(np.float64).copy()
    H = hessian.astype(np.float64).copy()

    # act_order ('ro') is the paper's per-channel reordering trick; with
    # group scales the boundaries would live in permuted space, so the
    # combination is rejected (the paper only evaluates ro with pc).
    assert not (act_order and group), "act_order requires per-channel scales"
    perm = None
    if act_order:
        perm = np.argsort(-np.diag(H)).astype(np.int64)
        W = W[perm, :]
        H = H[np.ix_(perm, perm)]

    # dead input dims
    dead = np.diag(H) == 0
    H[dead, dead] = 1.0
    W[dead, :] = 0.0

    damp = percdamp * np.mean(np.diag(H))
    H[np.arange(K), np.arange(K)] += damp
    # standard GPTQ: upper Cholesky factor of inv(H); row k holds the
    # error-propagation coefficients for input dim k.
    Hinv = np.linalg.inv(H)
    Hinv = np.linalg.cholesky((Hinv + Hinv.T) / 2).T

    if group == 0:
        if scale is None:
            _, scale = rtn_per_channel(w, bits)
        s_full = np.broadcast_to(scale[None, :], (K, N)).copy()
    else:
        s_full = np.empty((K, N))

    Q = np.zeros((K, N), np.int8)
    for k in range(K):
        if group and k % group == 0:
            # recompute group scales from the COMPENSATED weights
            blk = W[k:k + group, :]
            s_g = np.maximum(np.abs(blk).max(axis=0) / qmax, 1e-12)
            s_full[k:k + group, :] = s_g[None, :]
        wk = W[k, :]
        sk = s_full[k, :]
        q = np.clip(np.round(wk / sk), qmin, qmax)
        Q[k, :] = q.astype(np.int8)
        dq = q * sk
        err = (wk - dq) / Hinv[k, k]
        if k + 1 < K:
            W[k + 1:, :] -= np.outer(Hinv[k, k + 1:], err)

    if act_order:
        inv = np.empty_like(perm)
        inv[perm] = np.arange(K)
        Q = Q[inv, :]

    if group == 0:
        scales = s_full[0, :].astype(np.float32)
    else:
        scales = s_full.reshape(K // group, group, N)[:, 0, :] \
            .astype(np.float32)
    return Q, scales, perm


# --------------------------------------------------------------------------
# SmoothQuant / AWQ input-channel scaling (foldable linears only)
# --------------------------------------------------------------------------

def smoothquant_scales(act_absmax: np.ndarray, w: np.ndarray,
                       alpha: float = 0.5) -> np.ndarray:
    """s_j = max|X_j|^a / max|W_j|^(1-a) over input channels j (f32[K])."""
    wmax = np.maximum(np.abs(w).max(axis=1), 1e-8)
    s = np.power(np.maximum(act_absmax, 1e-8), alpha) / \
        np.power(wmax, 1.0 - alpha)
    return np.maximum(s, 1e-8).astype(np.float32)


def awq_scales(act_absmean: np.ndarray, w: np.ndarray, x_sample: np.ndarray,
               bits: int = 4, group: int = 64,
               alphas=np.arange(0.0, 1.01, 0.1)) -> np.ndarray:
    """AWQ-style activation-aware scale: grid over alpha minimizing the
    output MSE of the group-quantized scaled weights on a calib sample."""
    best_s, best_loss = np.ones(w.shape[0], np.float32), np.inf
    y_ref = x_sample @ w
    for a in alphas:
        s = np.power(np.maximum(act_absmean, 1e-8), a)
        s = (s / np.sqrt(s.max() * s.min() + 1e-12)).astype(np.float32)
        s = np.maximum(s, 1e-4)
        ws = w * s[:, None]
        q, sg = rtn_per_group(ws, group, bits)
        wdq = (q.reshape(-1, group, w.shape[1]).astype(np.float32)
               * sg[:, None, :]).reshape(w.shape) / s[:, None]
        loss = float(np.mean((x_sample @ wdq - y_ref) ** 2))
        if loss < best_loss:
            best_loss, best_s = loss, s
    return best_s
