"""Synthetic corpus / task generator invariants."""

import numpy as np

from compile import data


def test_corpus_deterministic():
    a = data.gen_corpus(5000, seed=3)
    b = data.gen_corpus(5000, seed=3)
    np.testing.assert_array_equal(a, b)
    c = data.gen_corpus(5000, seed=4)
    assert not np.array_equal(a, c)


def test_corpus_tokens_in_vocab():
    t = data.gen_corpus(20000, seed=0)
    assert t.min() >= 0 and t.max() < data.VOCAB


def test_cloze_targets_recoverable():
    tasks = data.gen_cloze(50)
    for t in tasks:
        # target is a noun and appears earlier in the context (coreference)
        assert data.NOUN0 <= t["target"] < data.NOUN0 + data.N_NOUN
        assert t["target"] in t["ctx"], "copy source must be in context"
        # final-sentence cue: context ends with ... THEN-THE
        assert t["ctx"][-1] == data.THE
        assert data.THEN in t["ctx"]


def test_mcq_well_formed():
    tasks = data.gen_mcq(50)
    for t in tasks:
        assert len(t["candidates"]) == 4
        assert 0 <= t["answer"] < 4
        right = t["candidates"][t["answer"]]
        wrong = [c for i, c in enumerate(t["candidates"])
                 if i != t["answer"]]
        rcls = data.noun_class(right - data.NOUN0)
        for w in wrong:
            assert data.noun_class(w - data.NOUN0) != rcls


def test_fewshot_answer_is_category():
    tasks = data.gen_fewshot(30)
    for t in tasks:
        assert len(t["candidates"]) == data.N_CAT
        # the context's final tokens are 'the NOUN isa'
        assert t["ctx"][-1] == data.ISA
        noun = t["ctx"][-2]
        assert data.noun_category(noun - data.NOUN0) == t["answer"]


def test_grammar_agreement_in_corpus():
    """When a subject class has a matching verb class, the sampled
    THE-NOUN-VERB trigram must obey it (classes without a match fall back
    to an arbitrary verb — the grammar's 'irregular verbs')."""
    g = data.Grammar(9)
    covered = {int(c) for c in g.verb_subj}
    checked = 0
    for _ in range(300):
        toks, subj = g.sentence()
        scls = data.noun_class(subj - data.NOUN0)
        if scls not in covered:
            continue
        i = toks.index(subj)
        verb = toks[i + 1]
        vcls = data.verb_class(verb - data.VERB0)
        assert int(g.verb_subj[vcls]) == scls
        checked += 1
    assert checked > 50
