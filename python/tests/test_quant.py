"""Quantization reference (compile/quant.py) property tests — the same
invariants the rust quant core asserts, so both sides stay honest."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quant


def rand_w(seed, k=32, n=8):
    return np.random.default_rng(seed).normal(size=(k, n)) \
        .astype(np.float32)


def calib(seed, t=128, k=32):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(t, k)).astype(np.float32)
    x[:, 3] *= 6.0  # outlier channel, like real activations
    h = (2.0 * x.T @ x / t).astype(np.float32)
    return x, h


class TestRtn:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 8]))
    def test_values_in_range(self, seed, bits):
        q, s = quant.rtn_per_channel(rand_w(seed), bits)
        lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
        assert q.min() >= lo and q.max() <= hi
        assert (s > 0).all()

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_group_beats_channel_mse(self, seed):
        w = rand_w(seed)
        qc, sc = quant.rtn_per_channel(w, 4)
        qg, sg = quant.rtn_per_group(w, 8, 4)
        mse_c = np.mean((quant.dequant_per_channel(qc, sc) - w) ** 2)
        wg = qg.reshape(4, 8, 8).astype(np.float32) * sg[:, None, :]
        mse_g = np.mean((wg.reshape(32, 8) - w) ** 2)
        assert mse_g <= mse_c + 1e-12


class TestLwc:
    def test_grid_never_hurts(self):
        w = rand_w(1, 128, 6)
        g, b = quant.lwc_grid_search(w, 4)
        qv, sv = quant.rtn_per_channel(w, 4)
        qc, sc = quant.rtn_per_channel(w, 4, g, b)
        mse_v = np.mean((quant.dequant_per_channel(qv, sv) - w) ** 2)
        mse_c = np.mean((quant.dequant_per_channel(qc, sc) - w) ** 2)
        assert mse_c <= mse_v + 1e-12

    def test_sgd_comparable_to_grid(self):
        # the paper's SGD-learned clipping should land near the grid
        # optimum on the same objective
        w = rand_w(2, 128, 4)
        w[np.abs(w) > 2.0] *= 3.0  # heavy tails
        gg, gb = quant.lwc_grid_search(w, 4)
        sg, sb = quant.lwc_sgd(w, 4, steps=150)

        def mse(gamma, beta):
            q, s = quant.rtn_per_channel(w, 4, gamma, beta)
            return np.mean((quant.dequant_per_channel(q, s) - w) ** 2)

        m_grid, m_sgd = mse(gg, gb), mse(sg, sb)
        m_van = mse(None, None)
        assert m_grid <= m_van
        # STE-SGD takes small steps on a piecewise-constant objective; it
        # must move in the right direction (improve on vanilla), while the
        # exhaustive grid remains the tighter optimum the rust port uses.
        assert m_sgd <= m_van + 1e-12
        assert m_grid <= m_sgd + 1e-12


class TestGptq:
    def test_beats_rtn_on_output_mse(self):
        w = rand_w(3)
        x, h = calib(4)
        q, s, _ = quant.gptq_quantize(w, h, 4)
        w_g = quant.dequant_per_channel(q, s)
        qr, sr = quant.rtn_per_channel(w, 4)
        w_r = quant.dequant_per_channel(qr, sr)
        e_g = np.mean((x @ w_g - x @ w) ** 2)
        e_r = np.mean((x @ w_r - x @ w) ** 2)
        assert e_g < e_r, f"gptq {e_g} vs rtn {e_r}"

    def test_act_order_permutation_valid(self):
        w = rand_w(5)
        _, h = calib(6)
        q, s, perm = quant.gptq_quantize(w, h, 4, act_order=True)
        assert sorted(perm.tolist()) == list(range(32))
        assert q.shape == w.shape

    def test_identity_hessian_is_rtn(self):
        w = rand_w(7)
        h = np.eye(32, dtype=np.float32)
        q, s, _ = quant.gptq_quantize(w, h, 4)
        qr, sr = quant.rtn_per_channel(w, 4)
        np.testing.assert_array_equal(q, qr)

    def test_group_act_order_rejected(self):
        w = rand_w(8)
        _, h = calib(9)
        try:
            quant.gptq_quantize(w, h, 4, act_order=True, group=8)
            raise RuntimeError("should have raised")
        except AssertionError:
            pass


class TestSmoothQuant:
    def test_forward_invariance(self):
        w = rand_w(10, 16, 8)
        x, _ = calib(11, 64, 16)
        s = quant.smoothquant_scales(np.abs(x).max(0), w, 0.5)
        y0 = x @ w
        y1 = (x / s[None, :]) @ (w * s[:, None])
        np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)

    def test_outlier_channel_scaled_up(self):
        w = rand_w(12, 16, 8)
        absmax = np.ones(16, np.float32)
        absmax[3] = 50.0
        s = quant.smoothquant_scales(absmax, w, 0.5)
        assert s[3] > s[(np.arange(16) != 3)].max()


class TestAwq:
    def test_scales_positive(self):
        w = rand_w(13, 16, 8)
        x, _ = calib(14, 64, 16)
        s = quant.awq_scales(np.abs(x).mean(0), w, x, bits=4, group=8)
        assert (s > 0).all() and np.isfinite(s).all()
