"""L2 model invariants: pallas path vs pure-jnp oracle, prefill/decode
consistency, KV-cache shapes, padding-mask correctness."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import configs, model

CFG = configs.ModelConfig("unit", d_model=64, n_layers=2, n_heads=4,
                          d_ff=96, vocab=64, max_seq=32)


@pytest.fixture(scope="module")
def ws():
    return model.init_weights(CFG, 0)


def toks(seed, b, s):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(3, CFG.vocab, size=(b, s)), jnp.int32)


@pytest.mark.parametrize("variant", configs.VARIANTS)
def test_pallas_matches_ref(ws, variant):
    t = toks(1, 2, 8)
    length = jnp.asarray([8, 8], jnp.int32)
    flat = model.quantize_weights(CFG, ws, variant, group=16)
    lr = np.asarray(model.prefill(CFG, variant, t, length, *flat,
                                  group=16, use_ref=True)[0])
    lp = np.asarray(model.prefill(CFG, variant, t, length, *flat,
                                  group=16, use_ref=False)[0])
    # int-quant boundaries amplify 1-ulp scale diffs; top-1 must agree
    assert np.abs(lr - lp).max() < 0.05
    assert (lr.argmax(-1) == lp.argmax(-1)).mean() > 0.95


def test_decode_consistent_with_prefill(ws):
    variant = "fp"
    t = toks(2, 2, 8)
    length = jnp.asarray([8, 8], jnp.int32)
    flat = model.quantize_weights(CFG, ws, variant, group=16)
    out = model.prefill(CFG, variant, t, length, *flat, group=16)
    logits = np.asarray(out[0])
    ks, vs = out[1:1 + CFG.n_layers], out[1 + CFG.n_layers:]
    # feed token at position 5; decode logits must equal prefill position 5
    dout = model.decode(CFG, variant, t[:, 5], jnp.asarray([5, 5], jnp.int32),
                        *ks, *vs, *flat, group=16)
    np.testing.assert_allclose(np.asarray(dout[0]), logits[:, 5],
                               rtol=1e-4, atol=1e-4)


def test_padding_mask_blocks_future(ws):
    """Row with length=4 must produce the same logits at position 3 as a
    row whose padding tokens differ — padding cannot leak."""
    variant = "fp"
    flat = model.quantize_weights(CFG, ws, variant, group=16)
    t1 = toks(3, 1, 8)
    t2 = np.asarray(t1).copy()
    t2[0, 4:] = 5  # different padding content
    length = jnp.asarray([4], jnp.int32)
    l1 = np.asarray(model.prefill(CFG, variant, t1, length, *flat,
                                  group=16)[0])
    l2 = np.asarray(model.prefill(CFG, variant, jnp.asarray(t2), length,
                                  *flat, group=16)[0])
    np.testing.assert_allclose(l1[0, 3], l2[0, 3], rtol=1e-5, atol=1e-5)


def test_kv_cache_shapes(ws):
    variant = "fp"
    flat = model.quantize_weights(CFG, ws, variant, group=16)
    t = toks(4, 1, 8)
    out = model.prefill(CFG, variant, t, jnp.asarray([8], jnp.int32), *flat,
                        group=16)
    assert len(out) == 1 + 2 * CFG.n_layers
    for c in out[1:]:
        assert c.shape == (1, CFG.n_heads, CFG.max_seq, CFG.head_dim)


def test_flat_param_entries_match_payloads(ws):
    for variant in configs.VARIANTS:
        flat = model.quantize_weights(CFG, ws, variant, group=16)
        ents = model.flat_param_entries(CFG, variant, group=16)
        assert len(flat) == len(ents)
        for arr, (_n, shape, dt) in zip(flat, ents):
            assert tuple(arr.shape) == tuple(shape)
            assert arr.dtype == dt


def test_batch_rows_independent(ws):
    """Each batch row's logits depend only on its own tokens."""
    variant = "fp"
    flat = model.quantize_weights(CFG, ws, variant, group=16)
    t = toks(5, 2, 8)
    length = jnp.asarray([8, 8], jnp.int32)
    both = np.asarray(model.prefill(CFG, variant, t, length, *flat,
                                    group=16)[0])
    solo = np.asarray(model.prefill(
        CFG, variant, t[:1], jnp.asarray([8], jnp.int32),
        *flat, group=16)[0])
    np.testing.assert_allclose(both[0], solo[0], rtol=1e-5, atol=1e-5)
