"""Pallas kernels vs the pure-jnp oracles — the CORE L1 correctness
signal, swept over shapes/dtypes with hypothesis."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import (asym, common, fastgemm, finegrained, fpgemm,
                             ref, w4a16, w8a8)

RTOL = 1e-5
ATOL = 1e-5


def rand_case(seed, m, k, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    return x, w


dims = st.tuples(
    st.integers(1, 5),            # m multiplier
    st.integers(1, 4),            # k multiplier (x16)
    st.integers(1, 4),            # n multiplier (x8)
    st.integers(0, 2 ** 31 - 1),  # seed
)


class TestFastGemm:
    @settings(max_examples=25, deadline=None)
    @given(dims)
    def test_matches_ref(self, case):
        mm, km, nm, seed = case
        m, k, n = 3 * mm, 16 * km, 8 * nm
        x, w = rand_case(seed, m, k, n)
        xq, sa = ref.quant_act_per_token(x)
        q, s = ref.quant_weight_per_channel_sym(w, 4)
        p = ref.pack_int4(q)
        got = fastgemm.gemm_w4a8_fast(xq, sa, p, s)
        want = ref.gemm_w4a8_fast(xq, sa, p, s)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_m1_decode_shape(self):
        x, w = rand_case(7, 1, 64, 48)
        xq, sa = ref.quant_act_per_token(x)
        q, s = ref.quant_weight_per_channel_sym(w, 4)
        p = ref.pack_int4(q)
        got = fastgemm.gemm_w4a8_fast(xq, sa, p, s)
        assert got.shape == (1, 48)
        np.testing.assert_allclose(
            got, ref.gemm_w4a8_fast(xq, sa, p, s), rtol=RTOL, atol=ATOL)

    def test_extreme_int4_values(self):
        # all-corners weights: every int4 value appears
        k, n = 16, 16
        q = jnp.asarray(
            np.tile(np.arange(-8, 8, dtype=np.int8)[:, None], (1, n)))
        p = ref.pack_int4(q)
        s = jnp.full((n,), 0.1, jnp.float32)
        x = jnp.asarray(np.eye(4, k, dtype=np.float32) * 127)
        xq, sa = ref.quant_act_per_token(x)
        got = fastgemm.gemm_w4a8_fast(xq, sa, p, s)
        want = ref.gemm_w4a8_fast(xq, sa, p, s)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
        # row 0 of eye picks weight row 0: check exact math
        np.testing.assert_allclose(
            np.asarray(got)[0],
            np.asarray(q)[0].astype(np.float32) * 0.1 * 127
            * np.asarray(sa)[0],
            rtol=1e-4)


class TestPacking:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 16), st.integers(1, 12),
           st.integers(0, 2 ** 31 - 1))
    def test_roundtrip(self, k2, n, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.integers(-8, 8, size=(2 * k2, n), dtype=np.int8))
        p = ref.pack_int4(q)
        assert p.dtype == jnp.uint8 and p.shape == (k2, n)
        np.testing.assert_array_equal(ref.unpack_int4(p), q)
        x16 = ref.unpack_int4_x16(p)
        np.testing.assert_array_equal(
            np.asarray(x16, np.int32), np.asarray(q, np.int32) * 16)

    def test_paper_example(self):
        # Fig. 5: -7 packs to low nibble 1001; high-nibble placement = -112
        q = jnp.asarray(np.array([[-7], [3]], np.int8))
        p = ref.pack_int4(q)
        assert int(p[0, 0]) == 0b0011_1001
        assert int(ref.unpack_int4_x16(p)[0, 0]) == -112


class TestW8A8:
    @settings(max_examples=20, deadline=None)
    @given(dims)
    def test_matches_ref(self, case):
        mm, km, nm, seed = case
        m, k, n = 2 * mm, 16 * km, 8 * nm
        x, w = rand_case(seed, m, k, n)
        xq, sa = ref.quant_act_per_token(x)
        q, s = ref.quant_weight_per_channel_sym(w, 8)
        np.testing.assert_allclose(
            w8a8.gemm_w8a8(xq, sa, q, s),
            ref.gemm_w8a8(xq, sa, q, s), rtol=RTOL, atol=ATOL)


class TestGrouped:
    # NOTE n >= 16: jax's CURRENT XLA-CPU backend has an LLVM-lowering bug
    # (add i32 + i8 type mismatch) for tiny int8 dots inside loops at
    # m=2, n=8; no model shape is that small.  Upstream issue, not ours.
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 3),
           st.integers(0, 2 ** 31 - 1))
    def test_matches_ref(self, mm, km, nm, seed):
        g = 16
        m, k, n = 2 * mm, g * 2 * km, 16 * nm
        x, w = rand_case(seed, m, k, n)
        xq, sa = ref.quant_act_per_token(x)
        q, s = ref.quant_weight_per_group_sym(w, g, 4)
        np.testing.assert_allclose(
            finegrained.gemm_w4a8_grouped(xq, sa, q, s, g),
            ref.gemm_w4a8_grouped(xq, sa, q, s, g), rtol=RTOL, atol=ATOL)


class TestAsym:
    @settings(max_examples=20, deadline=None)
    @given(dims)
    def test_matches_ref(self, case):
        mm, km, nm, seed = case
        m, k, n = 2 * mm, 16 * km, 8 * nm
        x, w = rand_case(seed, m, k, n)
        xq, sa = ref.quant_act_per_token(x)
        u, s, z = ref.quant_weight_per_channel_asym(w, 4)
        np.testing.assert_allclose(
            asym.gemm_w4a8_asym(xq, sa, u, s, z),
            ref.gemm_w4a8_asym(xq, sa, u, s, z), rtol=RTOL, atol=ATOL)

    def test_skewed_weights(self):
        # all-positive weights: asym must still reconstruct closely
        rng = np.random.default_rng(3)
        w = jnp.asarray(np.abs(rng.normal(size=(32, 8))).astype(np.float32))
        u, s, z = ref.quant_weight_per_channel_asym(w, 4)
        deq = (np.asarray(u, np.int32) - np.asarray(z)[None, :]) \
            * np.asarray(s)[None, :]
        assert np.abs(deq - np.asarray(w)).max() <= np.asarray(s).max() + 1e-6


class TestW4A16:
    @settings(max_examples=15, deadline=None)
    @given(dims)
    def test_matches_ref(self, case):
        mm, km, nm, seed = case
        g = 16
        m, k, n = 2 * mm, g * km, 8 * nm
        x, w = rand_case(seed, m, k, n)
        q, s = ref.quant_weight_per_group_sym(w, g, 4)
        np.testing.assert_allclose(
            w4a16.gemm_w4a16(x, q, s, g),
            ref.gemm_w4a16(x, q, s, g), rtol=RTOL, atol=ATOL)


class TestFpAndUnfused:
    def test_fp_matches(self):
        x, w = rand_case(5, 8, 64, 32)
        np.testing.assert_allclose(
            fpgemm.gemm_fp(x, w), ref.gemm_fp(x, w), rtol=RTOL, atol=1e-4)

    def test_unfused_equals_fused(self):
        # Fig. 4(b) vs (c): identical numerics, different kernel count
        x, w = rand_case(6, 8, 32, 16)
        xq, sa = ref.quant_act_per_token(x)
        q, s = ref.quant_weight_per_channel_sym(w, 4)
        p = ref.pack_int4(q)
        fused = fastgemm.gemm_w4a8_fast(xq, sa, p, s)
        unfused = fpgemm.gemm_w4a8_unfused(xq, sa, p, s)
        np.testing.assert_allclose(unfused, fused, rtol=RTOL, atol=ATOL)

    def test_convert_kernel_is_x16(self):
        rng = np.random.default_rng(8)
        q = jnp.asarray(rng.integers(-8, 8, size=(16, 8), dtype=np.int8))
        p = ref.pack_int4(q)
        w16 = fpgemm.convert_sint4_to_s8x16(p)
        np.testing.assert_array_equal(
            np.asarray(w16, np.int32), np.asarray(q, np.int32) * 16)


class TestActQuant:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 8), st.integers(2, 64),
           st.integers(0, 2 ** 31 - 1))
    def test_error_within_half_step(self, m, k, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32) * 10)
        q, s = ref.quant_act_per_token(x)
        deq = np.asarray(q, np.float32) * np.asarray(s)[:, None]
        err = np.abs(deq - np.asarray(x))
        assert (err <= np.asarray(s)[:, None] * 0.5 + 1e-6).all()

    def test_zero_row(self):
        x = jnp.zeros((2, 8), jnp.float32)
        q, s = ref.quant_act_per_token(x)
        assert (np.asarray(q) == 0).all() and (np.asarray(s) > 0).all()


class TestTiling:
    def test_largest_tile_divides(self):
        for dim in [1, 7, 128, 11088, 4096, 77]:
            t = common.largest_tile(dim, 128)
            assert dim % t == 0 and 1 <= t <= 128

    def test_vmem_budget_packed_half(self):
        full = common.vmem_bytes(128, 128, 1024, 1, 1.0)
        packed = common.vmem_bytes(128, 128, 1024, 1, 0.5)
        assert full - packed == 1024 * 128 // 2

    def test_mxu_estimate_bounds(self):
        assert common.mxu_util_estimate(128, 128, 1024) == 1.0
        assert common.mxu_util_estimate(1, 128, 1024) < 0.01
