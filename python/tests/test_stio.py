"""safetensors io roundtrip (the format the rust side mirrors)."""

import numpy as np

from compile import stio


def test_roundtrip(tmp_path):
    p = str(tmp_path / "t.safetensors")
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b.q": np.array([-8, 0, 7], np.int8),
        "c": np.array([1, 65535], np.uint16),
        "d": np.arange(4, dtype=np.int32),
    }
    stio.save(p, tensors)
    back = stio.load(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_header_is_8_aligned(tmp_path):
    p = str(tmp_path / "t.safetensors")
    stio.save(p, {"x": np.zeros(3, np.float32)})
    raw = open(p, "rb").read()
    n = int.from_bytes(raw[:8], "little")
    assert (8 + n) % 8 == 0
